//! Dependency-free telemetry: metrics registry, request tracing, and
//! hot-path profiling (DESIGN.md §Observability).
//!
//! The registry is process-global and lock-light: every handle returned by
//! [`counter`]/[`gauge`]/[`histogram`] is a `&'static` atomic cell, so the
//! hot path is a single `fetch_add` — the registration mutex is taken only
//! when a metric is first (or repeatedly, idempotently) registered, and at
//! scrape time. A scrape materializes a [`Snapshot`] — plain data that can
//! be rendered as Prometheus text ([`render_prometheus`], served at
//! `GET /metrics`), shipped over the replica RPC as JSON
//! ([`snapshot_to_json`]/[`snapshot_from_json`]), or folded across a fleet
//! ([`merge_fleet`]: summed aggregates plus per-replica `replica="K"`
//! labeled series — the same shape `GET /mem` uses for `MemReport`).
//!
//! Histograms use fixed log2 buckets (`le` = 1, 2, 4, …, 2^30, +Inf) over
//! integer units — microseconds by convention, stated in the metric name
//! (`*_us`) — so merging across processes is bucketwise addition with no
//! re-binning. Counter reads at scrape time are individually atomic but
//! not mutually consistent (a histogram's `sum` may be one observation
//! ahead of its `count`); the exposition is monotone, which is all
//! Prometheus-style rate math needs.

pub mod clock;
pub mod prof;
pub mod trace;

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;

/// Histogram bucket count: `le` = 2^0 .. 2^30 (31 finite bounds) + `+Inf`.
pub const HIST_BUCKETS: usize = 32;

/// Monotonically increasing event count.
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed level (in-flight requests, resident sessions, …).
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed log2-bucket histogram over non-negative integer observations.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Observe a duration in microseconds (the `*_us` convention).
    pub fn observe_us(&self, d: std::time::Duration) {
        self.observe(d.as_micros() as u64);
    }
}

/// Bucket index for an observation: smallest i with v <= 2^i, clamped to
/// the +Inf bucket. v = 0 and v = 1 both land in bucket 0 (le = 1).
pub fn bucket_index(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        ((u64::BITS - (v - 1).leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Upper bound of bucket i, or `None` for the +Inf bucket.
pub fn bucket_le(i: usize) -> Option<u64> {
    if i + 1 < HIST_BUCKETS {
        Some(1u64 << i)
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum Handle {
    C(&'static Counter),
    G(&'static Gauge),
    H(&'static Histogram),
}

struct Entry {
    name: String,
    help: String,
    labels: Vec<(String, String)>,
    handle: Handle,
}

fn registry() -> &'static Mutex<Vec<Entry>> {
    static R: OnceLock<Mutex<Vec<Entry>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Vec::new()))
}

fn register(name: &str, help: &str, labels: &[(&str, &str)], make: fn() -> Handle) -> Handle {
    let labels: Vec<(String, String)> =
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    let mut reg = registry().lock().unwrap();
    if let Some(e) = reg.iter().find(|e| e.name == name && e.labels == labels) {
        return e.handle;
    }
    let handle = make();
    reg.push(Entry { name: name.to_string(), help: help.to_string(), labels, handle });
    handle
}

/// Register (idempotently) and return an unlabeled counter.
pub fn counter(name: &str, help: &str) -> &'static Counter {
    counter_with(name, help, &[])
}

/// Register (idempotently) and return a labeled counter.
pub fn counter_with(name: &str, help: &str, labels: &[(&str, &str)]) -> &'static Counter {
    match register(name, help, labels, || {
        Handle::C(Box::leak(Box::new(Counter(AtomicU64::new(0)))))
    }) {
        Handle::C(c) => c,
        _ => panic!("metric `{name}` already registered with a different kind"),
    }
}

/// Register (idempotently) and return an unlabeled gauge.
pub fn gauge(name: &str, help: &str) -> &'static Gauge {
    match register(name, help, &[], || Handle::G(Box::leak(Box::new(Gauge(AtomicI64::new(0)))))) {
        Handle::G(g) => g,
        _ => panic!("metric `{name}` already registered with a different kind"),
    }
}

/// Register (idempotently) and return an unlabeled log2 histogram.
pub fn histogram(name: &str, help: &str) -> &'static Histogram {
    const Z: AtomicU64 = AtomicU64::new(0);
    match register(name, help, &[], || {
        Handle::H(Box::leak(Box::new(Histogram {
            buckets: [Z; HIST_BUCKETS],
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        })))
    }) {
        Handle::H(h) => h,
        _ => panic!("metric `{name}` already registered with a different kind"),
    }
}

// ---------------------------------------------------------------------------
// Snapshot
// ---------------------------------------------------------------------------

/// A scraped metric value (plain data; mergeable).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Counter(u64),
    Gauge(i64),
    Histogram { buckets: Vec<u64>, sum: u64, count: u64 },
}

impl Value {
    fn kind(&self) -> &'static str {
        match self {
            Value::Counter(_) => "counter",
            Value::Gauge(_) => "gauge",
            Value::Histogram { .. } => "histogram",
        }
    }

    /// Fold another value of the same kind into this one (fleet sums).
    fn merge(&mut self, o: &Value) {
        match (self, o) {
            (Value::Counter(a), Value::Counter(b)) => *a += b,
            (Value::Gauge(a), Value::Gauge(b)) => *a += b,
            (
                Value::Histogram { buckets: ab, sum: asum, count: ac },
                Value::Histogram { buckets: bb, sum: bsum, count: bc },
            ) => {
                for (a, b) in ab.iter_mut().zip(bb) {
                    *a += b;
                }
                *asum += bsum;
                *ac += bc;
            }
            _ => {} // kind mismatch: keep ours (cannot happen via registry)
        }
    }
}

/// One series: a metric name, its label set, and a scraped value.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub help: String,
    pub labels: Vec<(String, String)>,
    pub value: Value,
}

/// All series scraped at one instant, sorted by (name, labels).
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub series: Vec<Series>,
}

/// Scrape the process-global registry (plus profiling slots) right now.
pub fn snapshot() -> Snapshot {
    let mut series = Vec::new();
    {
        let reg = registry().lock().unwrap();
        for e in reg.iter() {
            let value = match e.handle {
                Handle::C(c) => Value::Counter(c.get()),
                Handle::G(g) => Value::Gauge(g.get()),
                Handle::H(h) => Value::Histogram {
                    buckets: h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
                    sum: h.sum.load(Ordering::Relaxed),
                    count: h.count.load(Ordering::Relaxed),
                },
            };
            series.push(Series {
                name: e.name.clone(),
                help: e.help.clone(),
                labels: e.labels.clone(),
                value,
            });
        }
    }
    prof::fold_into(&mut series);
    sort_series(&mut series);
    Snapshot { series }
}

fn sort_series(series: &mut [Series]) {
    series.sort_by(|a, b| a.name.cmp(&b.name).then_with(|| a.labels.cmp(&b.labels)));
}

/// Fold a fleet: the local (front-end) snapshot plus one snapshot per
/// replica. Output = aggregated sums over all sources, plus every replica
/// series repeated with a `replica="K"` label so per-worker skew stays
/// visible.
pub fn merge_fleet(local: Snapshot, replicas: &[(usize, Snapshot)]) -> Snapshot {
    let mut agg: Vec<Series> = local.series;
    for (_, snap) in replicas {
        for s in &snap.series {
            match agg.iter_mut().find(|a| a.name == s.name && a.labels == s.labels) {
                Some(a) => a.value.merge(&s.value),
                None => agg.push(s.clone()),
            }
        }
    }
    for (k, snap) in replicas {
        for s in &snap.series {
            let mut labels = s.labels.clone();
            labels.push(("replica".to_string(), k.to_string()));
            agg.push(Series { name: s.name.clone(), help: s.help.clone(), labels, value: s.value.clone() });
        }
    }
    sort_series(&mut agg);
    Snapshot { series: agg }
}

// ---------------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------------

/// Escape a label value: backslash, double-quote, newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape a HELP string: backslash and newline only (per the format spec).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Render a snapshot in the Prometheus text exposition format: families in
/// name order, one `# HELP`/`# TYPE` pair per family, series in label
/// order, cumulative histogram buckets with the `+Inf`/`_sum`/`_count`
/// contract. Deterministic for a given snapshot (golden-tested by
/// `python/tests/test_obs.py`).
pub fn render_prometheus(snap: &Snapshot) -> String {
    let mut out = String::new();
    let mut last_family: Option<&str> = None;
    for s in &snap.series {
        if last_family != Some(s.name.as_str()) {
            out.push_str(&format!("# HELP {} {}\n", s.name, escape_help(&s.help)));
            out.push_str(&format!("# TYPE {} {}\n", s.name, s.value.kind()));
            last_family = Some(s.name.as_str());
        }
        match &s.value {
            Value::Counter(v) => {
                out.push_str(&format!("{}{} {v}\n", s.name, label_block(&s.labels, None)));
            }
            Value::Gauge(v) => {
                out.push_str(&format!("{}{} {v}\n", s.name, label_block(&s.labels, None)));
            }
            Value::Histogram { buckets, sum, count } => {
                let mut cum = 0u64;
                for (i, b) in buckets.iter().enumerate() {
                    cum += b;
                    let le = match bucket_le(i) {
                        Some(b) => b.to_string(),
                        None => "+Inf".to_string(),
                    };
                    out.push_str(&format!(
                        "{}_bucket{} {cum}\n",
                        s.name,
                        label_block(&s.labels, Some(("le", &le)))
                    ));
                }
                out.push_str(&format!("{}_sum{} {sum}\n", s.name, label_block(&s.labels, None)));
                out.push_str(&format!(
                    "{}_count{} {count}\n",
                    s.name,
                    label_block(&s.labels, None)
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// JSON transport (the `metrics` replica RPC op)
// ---------------------------------------------------------------------------

/// Serialize a snapshot for the replica RPC (field-by-field, like
/// `mem_to_json`).
pub fn snapshot_to_json(snap: &Snapshot) -> Json {
    let series = snap
        .series
        .iter()
        .map(|s| {
            let labels = Json::Arr(
                s.labels
                    .iter()
                    .map(|(k, v)| Json::Arr(vec![Json::str(k), Json::str(v)]))
                    .collect(),
            );
            let mut pairs = vec![
                ("name", Json::str(&s.name)),
                ("help", Json::str(&s.help)),
                ("kind", Json::str(s.value.kind())),
                ("labels", labels),
            ];
            match &s.value {
                Value::Counter(v) => pairs.push(("value", Json::num(*v as f64))),
                Value::Gauge(v) => pairs.push(("value", Json::num(*v as f64))),
                Value::Histogram { buckets, sum, count } => {
                    pairs.push((
                        "buckets",
                        Json::Arr(buckets.iter().map(|&b| Json::num(b as f64)).collect()),
                    ));
                    pairs.push(("sum", Json::num(*sum as f64)));
                    pairs.push(("count", Json::num(*count as f64)));
                }
            }
            Json::obj(pairs)
        })
        .collect();
    Json::obj(vec![("series", Json::Arr(series))])
}

/// Parse a snapshot shipped by [`snapshot_to_json`] (None on shape errors).
pub fn snapshot_from_json(v: &Json) -> Option<Snapshot> {
    let mut series = Vec::new();
    for s in v.get("series")?.as_arr()? {
        let name = s.get("name")?.as_str()?.to_string();
        let help = s.get("help")?.as_str()?.to_string();
        let kind = s.get("kind")?.as_str()?;
        let mut labels = Vec::new();
        for l in s.get("labels")?.as_arr()? {
            let pair = l.as_arr()?;
            labels.push((pair.first()?.as_str()?.to_string(), pair.get(1)?.as_str()?.to_string()));
        }
        let value = match kind {
            "counter" => Value::Counter(s.get("value")?.as_f64()? as u64),
            "gauge" => Value::Gauge(s.get("value")?.as_f64()? as i64),
            "histogram" => {
                let buckets: Vec<u64> = s
                    .get("buckets")?
                    .as_arr()?
                    .iter()
                    .map(|b| b.as_f64().unwrap_or(0.0) as u64)
                    .collect();
                if buckets.len() != HIST_BUCKETS {
                    return None;
                }
                Value::Histogram {
                    buckets,
                    sum: s.get("sum")?.as_f64()? as u64,
                    count: s.get("count")?.as_f64()? as u64,
                }
            }
            _ => return None,
        };
        series.push(Series { name, help, labels, value });
    }
    let mut snap = Snapshot { series };
    sort_series(&mut snap.series);
    Some(snap)
}

// ---------------------------------------------------------------------------
// Serving metric handles (shared by coordinator + net layers)
// ---------------------------------------------------------------------------

/// All serving-path metric handles, registered once per process. The
/// front-end counters (`http_*`, `tokens_generated`, rejections) tick in
/// the process running `net/server.rs` — the router in fleet mode — while
/// the engine-side histograms (queue/prefill/decode) tick wherever the
/// coordinator runs, so a fleet scrape merges complementary series.
pub struct ServingMetrics {
    pub http_requests: &'static Counter,
    pub http_2xx: &'static Counter,
    pub http_4xx: &'static Counter,
    pub http_5xx: &'static Counter,
    pub tokens_generated: &'static Counter,
    pub admission_rejected: &'static Counter,
    pub draining_rejected: &'static Counter,
    pub streams_completed: &'static Counter,
    pub stream_errors: &'static Counter,
    pub inflight: &'static Gauge,
    pub ttfb_us: &'static Histogram,
    pub request_us: &'static Histogram,
    pub queue_wait_us: &'static Histogram,
    pub prefill_us: &'static Histogram,
    pub decode_round_us: &'static Histogram,
    pub write_stall_us: &'static Histogram,
}

/// The process-global serving metrics (registered on first use).
pub fn serving() -> &'static ServingMetrics {
    static S: OnceLock<ServingMetrics> = OnceLock::new();
    S.get_or_init(|| ServingMetrics {
        http_requests: counter("hyena_http_requests_total", "HTTP requests accepted off the wire"),
        http_2xx: counter_with(
            "hyena_http_responses_total",
            "HTTP responses by status class",
            &[("class", "2xx")],
        ),
        http_4xx: counter_with(
            "hyena_http_responses_total",
            "HTTP responses by status class",
            &[("class", "4xx")],
        ),
        http_5xx: counter_with(
            "hyena_http_responses_total",
            "HTTP responses by status class",
            &[("class", "5xx")],
        ),
        tokens_generated: counter(
            "hyena_tokens_generated_total",
            "Tokens written to client streams by the front end",
        ),
        admission_rejected: counter(
            "hyena_admission_rejected_total",
            "Requests bounced with 429 (admission backpressure)",
        ),
        draining_rejected: counter(
            "hyena_draining_rejected_total",
            "Requests bounced with 503 (draining or overloaded front door)",
        ),
        streams_completed: counter(
            "hyena_streams_completed_total",
            "SSE streams that ended with a done event",
        ),
        stream_errors: counter(
            "hyena_stream_errors_total",
            "SSE streams terminated by a server error event",
        ),
        inflight: gauge("hyena_inflight_requests", "Generate requests currently admitted"),
        ttfb_us: histogram("hyena_ttfb_us", "Time to first token event, microseconds"),
        request_us: histogram(
            "hyena_request_duration_us",
            "Full request duration (parse to stream end), microseconds",
        ),
        queue_wait_us: histogram(
            "hyena_queue_wait_us",
            "Admission queue wait before prefill, microseconds",
        ),
        prefill_us: histogram("hyena_prefill_us", "Prompt prefill duration, microseconds"),
        decode_round_us: histogram(
            "hyena_decode_round_us",
            "One batched decode round, microseconds",
        ),
        write_stall_us: histogram(
            "hyena_stream_write_stall_us",
            "Slow client socket writes (> 1ms), microseconds",
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(1 << 30), 30);
        assert_eq!(bucket_index((1 << 30) + 1), HIST_BUCKETS - 1);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
        // Every finite bound lands in its own bucket.
        for i in 0..HIST_BUCKETS - 1 {
            assert_eq!(bucket_index(1u64 << i), i.max(0));
        }
    }

    #[test]
    fn bucket_le_contract() {
        assert_eq!(bucket_le(0), Some(1));
        assert_eq!(bucket_le(1), Some(2));
        assert_eq!(bucket_le(HIST_BUCKETS - 2), Some(1 << (HIST_BUCKETS - 2)));
        assert_eq!(bucket_le(HIST_BUCKETS - 1), None);
    }

    #[test]
    fn registration_is_idempotent() {
        let a = counter("obs_test_idem_total", "x");
        let b = counter("obs_test_idem_total", "x");
        assert!(std::ptr::eq(a, b));
        a.inc();
        assert_eq!(b.get(), a.get());
    }

    #[test]
    fn labeled_counters_are_distinct_series() {
        let a = counter_with("obs_test_lbl_total", "x", &[("k", "a")]);
        let b = counter_with("obs_test_lbl_total", "x", &[("k", "b")]);
        assert!(!std::ptr::eq(a, b));
        a.add(3);
        b.add(5);
        let snap = snapshot();
        let vals: Vec<u64> = snap
            .series
            .iter()
            .filter(|s| s.name == "obs_test_lbl_total")
            .map(|s| match s.value {
                Value::Counter(v) => v,
                _ => panic!("kind"),
            })
            .collect();
        assert_eq!(vals, vec![3, 5]); // sorted by labels: k="a" then k="b"
    }

    #[test]
    fn histogram_exposition_contract() {
        let h = histogram("obs_test_hist_us", "y");
        h.observe(1);
        h.observe(3);
        h.observe(1 << 40); // +Inf bucket
        let snap = snapshot();
        let text = render_prometheus(&snap);
        assert!(text.contains("# TYPE obs_test_hist_us histogram"));
        assert!(text.contains("obs_test_hist_us_bucket{le=\"1\"} 1\n"));
        // Cumulative: le="4" includes both finite observations.
        assert!(text.contains("obs_test_hist_us_bucket{le=\"4\"} 2\n"));
        assert!(text.contains("obs_test_hist_us_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains(&format!("obs_test_hist_us_sum {}\n", 4 + (1u64 << 40))));
        assert!(text.contains("obs_test_hist_us_count 3\n"));
    }

    #[test]
    fn render_escapes_labels() {
        let c = counter_with("obs_test_esc_total", "z", &[("path", "a\"b\\c\nd")]);
        c.inc();
        let text = render_prometheus(&snapshot());
        assert!(text.contains("obs_test_esc_total{path=\"a\\\"b\\\\c\\nd\"} 1"));
    }

    #[test]
    fn json_roundtrip_preserves_snapshot() {
        let c = counter("obs_test_rt_total", "r");
        c.add(7);
        let h = histogram("obs_test_rt_us", "r");
        h.observe(100);
        let snap = snapshot();
        let back = snapshot_from_json(&snapshot_to_json(&snap)).expect("roundtrip");
        assert_eq!(back.series.len(), snap.series.len());
        for (a, b) in snap.series.iter().zip(&back.series) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.value, b.value);
        }
    }

    #[test]
    fn fleet_merge_sums_and_labels() {
        let mk = |v: u64| Snapshot {
            series: vec![Series {
                name: "m_total".into(),
                help: "m".into(),
                labels: vec![],
                value: Value::Counter(v),
            }],
        };
        let merged = merge_fleet(mk(1), &[(0, mk(10)), (1, mk(100))]);
        let agg = merged
            .series
            .iter()
            .find(|s| s.labels.is_empty())
            .expect("aggregate series");
        assert_eq!(agg.value, Value::Counter(111));
        let r1 = merged
            .series
            .iter()
            .find(|s| s.labels == vec![("replica".to_string(), "1".to_string())])
            .expect("replica series");
        assert_eq!(r1.value, Value::Counter(100));
        assert_eq!(merged.series.len(), 3);
    }

    #[test]
    fn fleet_merge_histograms_bucketwise() {
        let mk = |b0: u64| {
            let mut buckets = vec![0u64; HIST_BUCKETS];
            buckets[0] = b0;
            Snapshot {
                series: vec![Series {
                    name: "h_us".into(),
                    help: "h".into(),
                    labels: vec![],
                    value: Value::Histogram { buckets, sum: b0, count: b0 },
                }],
            }
        };
        let merged = merge_fleet(mk(2), &[(0, mk(3))]);
        let agg = merged.series.iter().find(|s| s.labels.is_empty()).unwrap();
        match &agg.value {
            Value::Histogram { buckets, sum, count } => {
                assert_eq!(buckets[0], 5);
                assert_eq!((*sum, *count), (5, 5));
            }
            _ => panic!("kind"),
        }
    }

    #[test]
    fn serving_handles_register_once() {
        let a = serving();
        let b = serving();
        assert!(std::ptr::eq(a.tokens_generated, b.tokens_generated));
    }
}
