//! The single time source for the telemetry layer (DESIGN.md
//! §Observability).
//!
//! Every wall-clock read in `rust/src/` routes through this module (or
//! through `net/mod.rs`, which delegates here): the rustcheck
//! nondeterminism lint allowlists exactly these two files, so a stray
//! `SystemTime::now()` anywhere else fails `scripts/check.sh lint-smoke`.
//! Span timestamps and profiling timers use the *monotonic* clock
//! ([`now_us`]/[`now_ns`]), anchored at the first read, so they never jump
//! under NTP adjustment; only log stamps and trace birth times use the
//! wall clock ([`epoch_ms`]).

use std::sync::OnceLock;
use std::time::Instant;

/// Milliseconds since the Unix epoch (wall clock; log/trace stamps only).
pub fn epoch_ms() -> u128 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis())
        .unwrap_or(0)
}

static START: OnceLock<Instant> = OnceLock::new();

/// The process-start anchor for the monotonic clock (first call wins).
fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

/// Monotonic microseconds since the first clock read in this process.
pub fn now_us() -> u64 {
    start().elapsed().as_micros() as u64
}

/// Monotonic nanoseconds since the first clock read in this process
/// (profiling timers; wraps after ~584 years).
pub fn now_ns() -> u64 {
    start().elapsed().as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_never_goes_backwards() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
        let n1 = now_ns();
        let n2 = now_ns();
        assert!(n2 >= n1);
    }

    #[test]
    fn ns_and_us_agree_on_scale() {
        let us = now_us();
        let ns = now_ns();
        // Same anchor: ns/1000 can only be ahead of the earlier us read.
        assert!(ns / 1000 >= us);
    }

    #[test]
    fn epoch_is_after_2020() {
        // 2020-01-01 in ms — a sanity floor, not a tight bound.
        assert!(epoch_ms() > 1_577_836_800_000);
    }
}
