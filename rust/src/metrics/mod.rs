//! Metrics: perplexity, accuracy, FLOP accounting (paper App. A.2 mirror),
//! throughput tracking.

pub mod flops;

/// Perplexity from mean NLL (nats).
pub fn perplexity(loss: f32) -> f32 {
    loss.exp()
}

/// Classification accuracy from logits `(B, C)` against labels `(B,)`.
pub fn class_accuracy(logits: &[f32], classes: usize, labels: &[i32]) -> f64 {
    let mut correct = 0usize;
    for (r, &lab) in labels.iter().enumerate() {
        let row = &logits[r * classes..(r + 1) * classes];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap();
        if argmax == lab {
            correct += 1;
        }
    }
    correct as f64 / labels.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform() {
        let v = 48.0f32;
        assert!((perplexity(v.ln()) - v).abs() < 1e-3);
    }

    #[test]
    fn class_accuracy_counts() {
        let logits = vec![1.0, 0.0, 0.0, 1.0, 0.0, 1.0];
        // rows: argmax 0, argmax 1, argmax 1 (classes=2)... wait 3 rows of 2
        let acc = class_accuracy(&logits, 2, &[0, 1, 0]);
        assert!((acc - 2.0 / 3.0).abs() < 1e-9);
    }
}
