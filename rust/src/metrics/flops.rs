//! FLOP accounting — host-side mirror of paper App. A.2 (and of
//! `python/compile/model.py::flops_per_token_lm`; the integration test
//! cross-checks this against manifest values).

/// Model shape needed for FLOP accounting.
#[derive(Debug, Clone)]
pub struct FlopShape {
    pub depth: usize,
    pub width: usize,
    pub seqlen: usize,
    pub vocab: usize,
    pub mlp_ratio: f64,
    pub order: usize,
    pub short_filter: usize,
    pub is_attention: bool,
}

/// Forward FLOPs per token (×2 for multiply+add), paper App. A.2:
///  i.   projections: order × D × D
///  ii.  short conv:  order × D × filter_len
///  iii. FFTConv:     5 × (order) × D × log2(L)
///  iv.  output:      D × D
/// Attention: 4 projections + 2 × L × D non-parametric (matrix + AV).
pub fn flops_per_token(s: &FlopShape) -> f64 {
    let d = s.width as f64;
    let l = s.seqlen as f64;
    let mlp = 2.0 * 2.0 * d * (d * s.mlp_ratio);
    let emb_head = 2.0 * d * s.vocab as f64;
    let mixer = if s.is_attention {
        2.0 * 4.0 * d * d + 2.0 * 2.0 * l * d
    } else {
        let n = s.order as f64;
        let proj = 2.0 * (n + 1.0) * d * d;
        let short = 2.0 * (n + 1.0) * d * s.short_filter as f64;
        let fftconv = 2.0 * 5.0 * n * d * l.max(2.0).log2();
        let out = 2.0 * d * d;
        proj + short + fftconv + out
    };
    s.depth as f64 * (mixer + mlp) + emb_head
}

/// Training FLOPs per optimizer step (fwd + bwd ≈ 3× fwd).
pub fn flops_per_step(s: &FlopShape, batch: usize) -> f64 {
    3.0 * flops_per_token(s) * batch as f64 * s.seqlen as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(is_attention: bool, seqlen: usize) -> FlopShape {
        FlopShape {
            depth: 4,
            width: 128,
            seqlen,
            vocab: 96,
            mlp_ratio: 4.0,
            order: 2,
            short_filter: 3,
            is_attention,
        }
    }

    #[test]
    fn hyena_beats_attention_at_long_l() {
        // The paper's FLOP reduction comes from the non-parametric attention
        // term growing with L while FFTConv grows with log L.
        let f_attn = flops_per_token(&base(true, 2048));
        let f_hyena = flops_per_token(&base(false, 2048));
        assert!(f_hyena < f_attn, "{f_hyena} !< {f_attn}");
    }

    #[test]
    fn attention_grows_linearly_in_l() {
        let f1 = flops_per_token(&base(true, 1024));
        let f2 = flops_per_token(&base(true, 4096));
        assert!(f2 > f1 + 1.0);
        // per-token parametric part constant; delta is 2·2·ΔL·D·depth
        let expected_delta = 4.0 * (4096.0 - 1024.0) * 128.0 * 4.0;
        assert!(((f2 - f1) - expected_delta).abs() < 1.0);
    }

    #[test]
    fn step_flops_scale_with_batch() {
        let s = base(false, 256);
        assert!((flops_per_step(&s, 16) / flops_per_step(&s, 8) - 2.0).abs() < 1e-9);
    }
}
