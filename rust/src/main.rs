//! `hyena` CLI — leader entrypoint for the coordinator.
//!
//! Subcommands:
//!   list                              list artifacts + built-in native configs
//!   train --model NAME [--steps N]    train on TinyPile (lm_*) or task data
//!   eval  --model NAME                held-out loss/ppl on TinyPile
//!   serve --model NAME [--requests N] run the batching server demo
//!   serve --model NAME --listen ADDR  HTTP/1.1 + SSE network front end
//!                                     (deadlines, 429 backpressure, drain)
//!   serve ... --listen ADDR --replicas N  same front end over N worker
//!                                     processes behind the least-loaded,
//!                                     session-affine router (net::router)
//!   replica --model NAME [--listen A] one worker's framed-RPC endpoint
//!                                     (spawned by `serve --replicas`)
//!   loadgen --addr HOST:PORT          chaos loadgen against a listener
//!                                     (repeat --addr to round-robin targets;
//!                                     --scrape checks /metrics invariants)
//!   dump-filters --model NAME [--out F] write filter CSV (Fig. D.5)
//!   info  --model NAME                print manifest summary
//!
//! Every subcommand takes `--backend native|pjrt|auto` (default `auto`,
//! also settable via `HYENA_BACKEND`). `auto` picks pjrt when the model's
//! artifact directory holds compiled HLO and native otherwise, so a fresh
//! checkout with no artifacts trains/serves out of the box.
//!
//! `--threads N` (or `HYENA_THREADS=N`; default: available parallelism)
//! sizes the process-wide worker pool that the native backend's
//! row-parallel engine runs on — training steps and the batching server
//! share the same pool, so concurrent components never oversubscribe the
//! machine.

use std::net::SocketAddr;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use hyena::backend::{self, Backend, BackendKind};
use hyena::backend::native::NativeConfig;
use hyena::coordinator::generation::Sampling;
use hyena::coordinator::server::{Engine, GenerateRequest, Server};
use hyena::coordinator::trainer::{eval_loss, Trainer};
use hyena::data::corpus::{generate, CorpusConfig};
use hyena::data::dataset::LmBatches;
use hyena::net::client::{LoadGenConfig, LoadReport};
use hyena::net::router::{FleetConfig, FleetHandle, ReplicaServer};
use hyena::net::server::NetServer;
use hyena::net::{ChaosConfig, NetConfig};
use hyena::runtime::checkpoint::Checkpoint;
use hyena::runtime::Manifest;
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;

fn main() -> Result<()> {
    let args = Args::parse(&[
        "quiet",
        "greedy",
        "mixed",
        "require-buckets",
        "stream-decode",
        "burst",
        "scrape",
    ]);
    // Size the shared worker pool before any backend is constructed (models
    // capture the pool at load time).
    if let Some(t) = args.get("threads") {
        let n: usize = t
            .parse()
            .map_err(|_| anyhow!("--threads wants a positive integer, got {t:?}"))?;
        if n == 0 {
            bail!("--threads must be ≥ 1");
        }
        hyena::util::pool::configure(n);
    }
    match args.positional.first().map(String::as_str) {
        Some("list") => cmd_list(),
        Some("info") => cmd_info(&args),
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("replica") => cmd_replica(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("dump-filters") => cmd_dump_filters(&args),
        _ => {
            eprintln!(
                "usage: hyena <list|info|train|eval|serve|replica|loadgen|dump-filters> \
                 [--model NAME] [--backend native|pjrt|auto] [--threads N] \
                 [--steps N] [--seed S] [--buckets N] [--max-context N] [--mixed] \
                 [--require-buckets] [--stream-decode] [--listen ADDR] \
                 [--replicas N] [--addr HOST:PORT]... [--chaos SPEC] [--burst] \
                 [--scrape]"
            );
            Ok(())
        }
    }
}

fn model_arg(args: &Args) -> Result<String> {
    args.get("model")
        .map(str::to_string)
        .ok_or_else(|| anyhow!("--model NAME required (see `hyena list`)"))
}

/// Resolve `--backend` / `HYENA_BACKEND` / autodetection for `dir`.
fn backend_kind(args: &Args, dir: &Path) -> Result<BackendKind> {
    BackendKind::parse(args.get_or("backend", "auto"), dir)
}

fn load_model(args: &Args, name: &str, seed: i32) -> Result<(Box<dyn Backend>, BackendKind)> {
    let dir = hyena::artifact(name);
    let kind = backend_kind(args, &dir)?;
    let model = backend::load(kind, &dir, seed)?;
    Ok((model, kind))
}

fn cmd_list() -> Result<()> {
    let dir = hyena::artifacts_dir();
    let mut names: Vec<String> = match std::fs::read_dir(&dir) {
        Ok(entries) => entries
            .filter_map(|e| e.ok())
            .filter(|e| e.path().join("manifest.json").exists())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect(),
        Err(_) => Vec::new(),
    };
    names.sort();
    if names.is_empty() {
        println!("(no compiled artifacts under {})", dir.display());
    }
    for n in &names {
        println!("{n}");
    }
    println!("\nbuilt-in native configs (no artifacts needed, --backend native):");
    for n in NativeConfig::builtin_names() {
        if !names.iter().any(|a| a == n) {
            println!("{n}");
        }
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let dir = hyena::artifact(&name);
    let kind = backend_kind(args, &dir)?;
    // pjrt: read the manifest straight off disk. native: synthesize it
    // (cheap — parameters for these model sizes initialize in milliseconds).
    let man: Manifest = match kind {
        BackendKind::Pjrt => Manifest::load(&dir)?,
        BackendKind::Native => backend::load(kind, &dir, 0)?.manifest().clone(),
    };
    println!("name           {}", man.name);
    println!("backend        {}", kind.name());
    println!("family         {}", man.family());
    println!("params         {} tensors, {} elements", man.params.len(), man.numel());
    println!("batch x seqlen {} x {}", man.batch()?, man.seqlen()?);
    println!("train_step     {}", man.has_train_step);
    if let Some(f) = man.flops_per_step {
        println!("flops/step     {f:.3e}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let steps = args.get_u64("steps", 300);
    let seed = args.get_u64("seed", 0);
    let (mut model, kind) = load_model(args, &name, seed as i32)?;
    println!("loaded {name} (backend: {})", kind.name());
    if model.manifest().family() != "lm" {
        bail!("`hyena train` drives LM models; use the examples/ for img");
    }
    let corpus = generate(&CorpusConfig { seed, ..Default::default() }, 400);
    println!(
        "TinyPile: {} train / {} val tokens",
        corpus.train.len(),
        corpus.val.len()
    );
    let b = model.manifest().batch()?;
    let l = model.manifest().seqlen()?;
    let vocab = model.manifest().vocab()?;
    if let Some(ckpt_path) = args.get("restore") {
        let ckpt = Checkpoint::load(Path::new(ckpt_path))?;
        model.set_step(ckpt.step);
        let params = ckpt.into_params(model.manifest())?;
        model.set_params(&params)?;
        println!("restored checkpoint at step {}", model.step());
    }
    let mut batches = LmBatches::new(&corpus.train, b, l, seed).with_vocab(vocab);
    let report = {
        let mut trainer = Trainer::new(model.as_mut(), move || batches.next_batch());
        trainer.quiet = args.flag("quiet");
        trainer.run(steps)?
    };
    if let Some(save_path) = args.get("save").map(str::to_string) {
        let names: Vec<String> =
            model.manifest().params.iter().map(|p| p.name.clone()).collect();
        let tensors = model.params_host()?;
        let ckpt = Checkpoint {
            step: model.step(),
            tensors: names.into_iter().zip(tensors).collect(),
        };
        ckpt.save(Path::new(&save_path))?;
        println!("saved checkpoint -> {save_path}");
    }
    println!(
        "done: loss {:.4}  {:.2} steps/s  {:.0} tok/s",
        report.final_loss, report.steps_per_s, report.tokens_per_s
    );
    if let Some(mem) = &report.mem {
        println!(
            "train arena hiwater {} KiB ({} allocs)",
            mem.train_arena_hiwater_bytes / 1024,
            mem.train_arena_allocs
        );
    }
    let evals = LmBatches::eval_batches_vocab(&corpus.val, b, l, vocab);
    if !evals.is_empty() {
        let n = evals.len().min(4);
        let mut i = 0;
        let nll = eval_loss(
            model.as_ref(),
            &mut || {
                let batch = evals[i].clone();
                i += 1;
                batch
            },
            n,
        )?;
        println!("val loss {:.4}  ppl {:.2}", nll, nll.exp());
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let seed = args.get_u64("seed", 0);
    let (mut model, _) = load_model(args, &name, seed as i32)?;
    if let Some(ckpt_path) = args.get("restore") {
        let ckpt = Checkpoint::load(Path::new(ckpt_path))?;
        model.set_step(ckpt.step);
        let params = ckpt.into_params(model.manifest())?;
        model.set_params(&params)?;
        println!("restored checkpoint at step {}", model.step());
    }
    let corpus = generate(&CorpusConfig { seed, ..Default::default() }, 400);
    let b = model.manifest().batch()?;
    let l = model.manifest().seqlen()?;
    let evals = LmBatches::eval_batches_vocab(&corpus.val, b, l, model.manifest().vocab()?);
    let n = evals.len().min(8);
    let mut i = 0;
    let nll = eval_loss(
        model.as_ref(),
        &mut || {
            let batch = evals[i].clone();
            i += 1;
            batch
        },
        n,
    )?;
    let provenance = if args.get("restore").is_some() {
        format!("restored, step {}", model.step())
    } else {
        "untrained init; pass --restore CKPT".to_string()
    };
    println!("{name}: val loss {:.4}  ppl {:.2} ({provenance})", nll, nll.exp());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    // `--listen --replicas N`: no local engine — spawn N worker processes
    // and put the router in front of them.
    if let (Some(listen), Some(_)) = (args.get("listen"), args.get("replicas")) {
        let listen = listen.to_string();
        return serve_fleet(args, &name, &listen);
    }
    let n_req = args.get_usize("requests", 16);
    let seed = args.get_u64("seed", 0);
    let buckets = args.get("buckets").and_then(|v| v.parse::<usize>().ok());
    let max_context = args.get("max-context").and_then(|v| v.parse::<usize>().ok());
    let mixed = args.flag("mixed");
    let dir = hyena::artifact(&name);
    let kind = backend_kind(args, &dir)?;
    // Read shapes through a cheap probe load for native; pjrt reads the
    // manifest without compiling.
    let (l, vocab) = match kind {
        BackendKind::Pjrt => {
            let man = Manifest::load(&dir)?;
            (man.seqlen()?, man.vocab()?)
        }
        BackendKind::Native => {
            let probe = backend::load(kind, &dir, 0)?;
            (probe.manifest().seqlen()?, probe.manifest().vocab()?)
        }
    };
    let server = Server::start_kind(
        kind,
        dir,
        seed as i32,
        Duration::from_millis(20),
        None,
        buckets,
        max_context,
    )?;
    // `--listen` switches the demo driver off: expose the engine over the
    // HTTP/SSE front end and serve until drained (SIGTERM/ctrl-c).
    if let Some(listen) = args.get("listen").map(str::to_string) {
        return serve_net(args, server, &listen, kind);
    }
    println!("server up (backend: {}); firing {n_req} requests", kind.name());
    // The serving window: the compiled shape unless --max-context extended
    // it (prompts past the largest bucket prefill via overlap-save chunks).
    let l = max_context.unwrap_or(l).max(l);
    let mut rng = Pcg::new(seed);
    let sampling = if args.flag("greedy") {
        Sampling::Greedy
    } else {
        Sampling::Temperature { t: 0.8, top_k: 16 }
    };
    // Prompt lengths: fixed (default 8) or a mixed ladder exercising every
    // serving bucket (`--mixed`, the serve-smoke gate's traffic shape).
    let base_len = args.get_usize("prompt-len", 8).clamp(1, l.saturating_sub(2).max(1));
    let mixed_lens = [
        (l / 8).max(1),
        (l / 4).max(1),
        (l / 2).max(1),
        (3 * l / 4).min(l.saturating_sub(2)).max(1),
    ];
    let reqs: Vec<(Vec<i32>, usize)> = (0..n_req)
        .map(|i| {
            let plen = if mixed { mixed_lens[i % mixed_lens.len()] } else { base_len };
            let prompt: Vec<i32> =
                (0..plen).map(|_| rng.usize_below(vocab) as i32).collect();
            let max_new = 8.min(l.saturating_sub(plen + 1)).max(1);
            (prompt, max_new)
        })
        .collect();
    let handles: Vec<_> = reqs
        .iter()
        .map(|(prompt, max_new)| {
            server.handle.submit(GenerateRequest {
                prompt: prompt.clone(),
                max_new: *max_new,
                sampling,
                deadline: None,
                trace_id: 0,
            })
        })
        .collect();
    let mut total = Duration::ZERO;
    let mut total_tokens = 0usize;
    let mut routed: Vec<(usize, usize)> = Vec::new(); // (prompt len, bucket)
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.recv().map_err(|_| anyhow!("worker died"))??;
        total += resp.total_time;
        total_tokens += resp.tokens.len();
        routed.push((reqs[i].0.len(), resp.bucket_len));
        println!(
            "  req {i:>3}: prompt {:>4} -> {} tokens, bucket {:>5}, queue {:?}, \
             total {:?}, batch x{}",
            reqs[i].0.len(),
            resp.tokens.len(),
            resp.bucket_len,
            resp.queue_time,
            resp.total_time,
            resp.batch_occupancy
        );
    }
    println!("mean latency {:?}", total / n_req as u32);

    // Serve report: bucket routing, decode sessions, workspace high-water.
    if let Some(mem) = server.handle.mem_report() {
        println!(
            "serve report: {} inference forwards, buckets {:?}, hits {:?}, kernel {}",
            mem.serve_forwards,
            mem.bucket_lens,
            mem.bucket_hits,
            if mem.kernel.is_empty() { "-" } else { &mem.kernel }
        );
        println!(
            "  decode sessions: {} begun ({} live), {} streamed steps \
             ({} batched rounds x {} rows), session state {} KiB",
            mem.decode_sessions_total,
            mem.decode_sessions_live,
            mem.decode_steps,
            mem.decode_step_batches,
            mem.decode_step_batch_rows,
            mem.decode_state_bytes / 1024
        );
        println!(
            "  serve arena hiwater {} KiB ({} allocs), cached filters {} KiB",
            mem.serve_arena_hiwater_bytes / 1024,
            mem.serve_arena_allocs,
            mem.serve_spec_bytes / 1024
        );
        if !mem.ext_bucket_lens.is_empty() || mem.prefill_chunked > 0 {
            println!(
                "  long-context: window {}, ext buckets {:?}, {} chunked prefills \
                 ({} chunks), chunk workspace {} KiB",
                mem.max_context,
                mem.ext_bucket_lens,
                mem.prefill_chunked,
                mem.prefill_chunks,
                mem.prefill_chunk_bytes / 1024
            );
        }
        if args.flag("require-buckets") {
            // The smoke gate: every request's *prefill* must have been
            // routed to the smallest bucket covering its prompt — a short
            // prompt prefilled through the full-L plan is the padding waste
            // this path exists to remove. (Decode steps after prefill are
            // bucket-free: they run at a single position from the session
            // state.)
            if mem.bucket_lens.len() < 2 {
                bail!("--require-buckets: engine reports a single bucket ({:?})", mem.bucket_lens);
            }
            let full = *mem.bucket_lens.last().unwrap();
            let mut expect_below_full = false;
            for (i, &(plen, got)) in routed.iter().enumerate() {
                let want = mem.bucket_lens.iter().copied().find(|&b| b >= plen).unwrap_or(full);
                expect_below_full |= want < full;
                if got != want {
                    bail!(
                        "--require-buckets: request {i} (prompt len {plen}) \
                         was routed to bucket {got}, expected {want} — full-pad fallback"
                    );
                }
            }
            // The check above recomputes the router's own formula, so it
            // cannot see an engine-side regression. bucket_hits is counted
            // at the point of *plan selection* inside the inference
            // forward: if short prompts exist but every executed prefill
            // ran the full plan, the serving path is full-padding.
            if expect_below_full {
                let below: u64 =
                    mem.bucket_hits.iter().take(mem.bucket_hits.len().saturating_sub(1)).sum();
                if below == 0 {
                    bail!(
                        "--require-buckets: short prompts were present but every \
                         inference forward executed the full-{full} plan \
                         (hits {:?}) — full-pad fallback in the engine",
                        mem.bucket_hits
                    );
                }
            }
            println!("bucket routing verified: no full-pad fallback");
        }
        if args.flag("stream-decode") {
            // The decode-smoke gate: generation must have flowed through
            // resident sessions and the streaming step path, not prefix
            // recompute. Every request begins a session; every generated
            // token beyond a request's first costs exactly one streamed
            // step, so the counters are fully determined.
            if mem.decode_sessions_total < n_req as u64 {
                bail!(
                    "--stream-decode: {} requests but only {} decode sessions begun \
                     — the server is not session-based",
                    n_req,
                    mem.decode_sessions_total
                );
            }
            let want_steps = total_tokens.saturating_sub(n_req) as u64;
            if mem.decode_steps < want_steps {
                bail!(
                    "--stream-decode: {total_tokens} tokens generated across {n_req} \
                     requests but only {} streamed steps (expected ≥ {want_steps}) \
                     — decode is recomputing prefixes",
                    mem.decode_steps
                );
            }
            if mem.decode_sessions_live != 0 {
                bail!(
                    "--stream-decode: {} sessions still live after all replies \
                     — session state is leaking",
                    mem.decode_sessions_live
                );
            }
            println!(
                "streaming decode verified: {} sessions, {} streamed steps",
                mem.decode_sessions_total, mem.decode_steps
            );
        }
    } else if args.flag("require-buckets") {
        bail!("--require-buckets: backend exposes no serve report");
    } else if args.flag("stream-decode") {
        bail!("--stream-decode: backend exposes no serve report");
    }
    server.stop();
    Ok(())
}

/// Resolve `--chaos SPEC` (explicit) or `HYENA_CHAOS` (ambient) — malformed
/// specs are hard errors so a typo'd chaos run can't silently pass.
fn chaos_arg(args: &Args) -> Result<ChaosConfig> {
    match args.get("chaos") {
        Some(spec) => ChaosConfig::parse(spec).map_err(|e| anyhow!("--chaos: {e}")),
        None => ChaosConfig::from_env().map_err(|e| anyhow!("HYENA_CHAOS: {e}")),
    }
}

/// `--listen`-family NetConfig from the shared CLI surface.
fn net_config(args: &Args, listen: &str) -> Result<NetConfig> {
    Ok(NetConfig {
        addr: listen.to_string(),
        conn_threads: args.get_usize("conn-threads", 32),
        queue_cap: args.get_usize("queue-cap", 0),
        token_buf: args.get_usize("token-buf", 128),
        deadline_ms: args.get_u64("deadline-ms", 30_000),
        drain_ms: args.get_u64("drain-ms", 5_000),
        io_timeout_ms: args.get_u64("io-timeout-ms", 10_000),
        max_body_bytes: args.get_usize("max-body-bytes", 4 << 20),
        chaos: chaos_arg(args)?,
        quiet: args.flag("quiet"),
    })
}

/// `serve --listen ADDR`: the HTTP/1.1 + SSE network front end. Runs until
/// SIGTERM/ctrl-c, then drains (finish live streams, bounded by
/// `--drain-ms`) and exits nonzero if any decode session leaked.
fn serve_net(args: &Args, server: Server, listen: &str, kind: BackendKind) -> Result<()> {
    let cfg = net_config(args, listen)?;
    if !cfg.chaos.is_off() {
        println!(
            "chaos enabled: disconnect {:.2} stall {:.2} garbage {:.2} \
             (stall_ms {}, seed {})",
            cfg.chaos.disconnect, cfg.chaos.stall, cfg.chaos.garbage,
            cfg.chaos.stall_ms, cfg.chaos.seed
        );
    }
    hyena::net::server::install_drain_signals();
    let net = NetServer::start(server.handle.clone(), cfg)?;
    // check.sh greps this line for the bound port — keep the spelling.
    println!(
        "listening on {} (backend: {}, capacity {}); SIGTERM/ctrl-c drains",
        net.addr(),
        kind.name(),
        server.handle.capacity()
    );
    let report = net.run_until_drained()?;
    let s = &report.stats;
    println!(
        "serve-net: {} conns, {} requests ({} 2xx, {} 4xx incl {} 429, {} 5xx), \
         {} streams, {} tokens",
        s.conns, s.requests, s.s2xx, s.s4xx, s.s429, s.s5xx, s.streams, s.tokens
    );
    if s.chaos_disconnects + s.chaos_stalls > 0 {
        println!(
            "  chaos injected: {} disconnects, {} stalls",
            s.chaos_disconnects, s.chaos_stalls
        );
    }
    println!(
        "drain: {} finished, {} aborted, {} dropped queued, {} leaked sessions",
        report.drain.finished,
        report.drain.aborted,
        report.drain.dropped_queued,
        report.leaked_sessions
    );
    server.stop();
    if report.leaked_sessions > 0 {
        bail!("{} decode sessions leaked across drain", report.leaked_sessions);
    }
    Ok(())
}

/// `replica`: one worker process — the in-process session engine behind
/// the framed-RPC endpoint the router dials (`net::router`). Runs until
/// SIGTERM or stdin EOF (the parent-death watcher: `serve --replicas`
/// holds our stdin pipe, so a dead router means EOF and we self-drain
/// instead of serving unreachable sessions forever).
fn cmd_replica(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let seed = args.get_u64("seed", 0);
    let buckets = args.get("buckets").and_then(|v| v.parse::<usize>().ok());
    let max_context = args.get("max-context").and_then(|v| v.parse::<usize>().ok());
    let dir = hyena::artifact(&name);
    let kind = backend_kind(args, &dir)?;
    let server = Server::start_kind(
        kind,
        dir,
        seed as i32,
        Duration::from_millis(20),
        None,
        buckets,
        max_context,
    )?;
    let handle = server.handle.clone();
    let qc = args.get_usize("queue-cap", 0);
    handle.set_queue_cap(if qc == 0 { handle.capacity() } else { qc });
    let mut rs = ReplicaServer::start(handle.clone(), args.get_or("listen", "127.0.0.1:0"))?;
    // The router's spawn path parses this line for the bound port — keep
    // the spelling.
    println!(
        "replica listening on {} (backend: {}, capacity {})",
        rs.addr(),
        kind.name(),
        handle.capacity()
    );
    hyena::net::server::install_drain_signals();
    let stdin_eof = Arc::new(AtomicBool::new(false));
    {
        let stdin_eof = Arc::clone(&stdin_eof);
        std::thread::spawn(move || {
            use std::io::Read;
            let mut buf = [0u8; 256];
            let mut stdin = std::io::stdin();
            loop {
                match stdin.read(&mut buf) {
                    Ok(0) | Err(_) => {
                        stdin_eof.store(true, Ordering::SeqCst);
                        return;
                    }
                    Ok(_) => {}
                }
            }
        });
    }
    while !hyena::net::server::drain_signalled() && !stdin_eof.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(50));
    }
    let rep = handle
        .drain(Duration::from_millis(args.get_u64("drain-ms", 5_000)))
        .unwrap_or_default();
    rs.stop();
    let leaked = handle.mem_report().map_or(0, |m| m.decode_sessions_live) as usize;
    println!(
        "drain: {} finished, {} aborted, {} dropped queued, {} leaked sessions",
        rep.finished, rep.aborted, rep.dropped_queued, leaked
    );
    server.stop();
    if leaked > 0 {
        bail!("{leaked} decode sessions leaked across drain");
    }
    Ok(())
}

/// Child argv for one replica worker: the `replica` subcommand plus every
/// engine-shaping option passed through verbatim, so all workers serve
/// identical models (token-identity across the fleet depends on it).
fn replica_argv(args: &Args, name: &str) -> Vec<String> {
    let mut v = vec![
        "replica".to_string(),
        "--model".to_string(),
        name.to_string(),
        "--listen".to_string(),
        "127.0.0.1:0".to_string(),
    ];
    for key in ["backend", "seed", "buckets", "max-context", "threads", "queue-cap", "drain-ms"] {
        if let Some(val) = args.get(key) {
            v.push(format!("--{key}"));
            v.push(val.to_string());
        }
    }
    if args.flag("quiet") {
        v.push("--quiet".to_string());
    }
    v
}

/// Spawn one replica worker and wait for its address line. Stdin is a
/// pipe we hold (the child's parent-death watcher); stdout is drained on
/// a forwarding thread so the child can never block on a full pipe.
fn spawn_replica(
    exe: &Path,
    argv: &[String],
    k: usize,
    quiet: bool,
) -> Result<(std::process::Child, SocketAddr)> {
    use std::io::BufRead;
    let mut child = std::process::Command::new(exe)
        .args(argv)
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .spawn()
        .with_context(|| format!("spawn replica {k}"))?;
    let stdout = child.stdout.take().ok_or_else(|| anyhow!("replica {k}: no stdout"))?;
    let mut rd = std::io::BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        if rd.read_line(&mut line)? == 0 {
            bail!("replica {k} exited before reporting its address");
        }
        if !quiet {
            print!("[replica {k}] {line}");
        }
        if let Some(rest) = line.trim().strip_prefix("replica listening on ") {
            let tok = rest.split_whitespace().next().unwrap_or("");
            break tok
                .parse::<SocketAddr>()
                .map_err(|_| anyhow!("replica {k}: bad address {tok:?}"))?;
        }
    };
    std::thread::spawn(move || {
        let mut line = String::new();
        loop {
            line.clear();
            match rd.read_line(&mut line) {
                Ok(0) | Err(_) => return,
                Ok(_) => {
                    if !quiet {
                        print!("[replica {k}] {line}");
                    }
                }
            }
        }
    });
    Ok((child, addr))
}

/// `serve --listen ADDR --replicas N`: spawn N single-engine worker
/// processes, put the least-loaded/session-affine router in front, and
/// serve the same HTTP front end. A supervisor respawns dead workers (the
/// fleet marks them down meanwhile); SIGTERM drains fleet-wide.
fn serve_fleet(args: &Args, name: &str, listen: &str) -> Result<()> {
    let n = args.get_usize("replicas", 2).max(1);
    let quiet = args.flag("quiet");
    let exe = std::env::current_exe().context("current_exe")?;
    let argv = replica_argv(args, name);
    let mut kids = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for k in 0..n {
        let (child, addr) = spawn_replica(&exe, &argv, k, quiet)?;
        kids.push(child);
        addrs.push(addr);
    }
    let fleet = FleetHandle::connect(&addrs, FleetConfig { quiet, ..FleetConfig::default() })?;
    hyena::net::server::install_drain_signals();
    let net = NetServer::start_engine(Box::new(fleet.clone()), net_config(args, listen)?)?;
    // check.sh greps this line for the bound port — keep the spelling.
    println!(
        "listening on {} (backend: router x{n}, capacity {}); SIGTERM/ctrl-c drains",
        net.addr(),
        fleet.capacity()
    );
    let children = Arc::new(Mutex::new(kids));
    let stop = Arc::new(AtomicBool::new(false));
    let sup = {
        let children = Arc::clone(&children);
        let stop = Arc::clone(&stop);
        let fleet = fleet.clone();
        let exe = exe.clone();
        let argv = argv.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(Duration::from_millis(200));
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let mut kids = match children.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            for (k, child) in kids.iter_mut().enumerate() {
                if let Ok(Some(status)) = child.try_wait() {
                    eprintln!("[router] replica {k} exited ({status}); respawning");
                    match spawn_replica(&exe, &argv, k, quiet) {
                        Ok((c, addr)) => {
                            *child = c;
                            fleet.set_replica_addr(k, addr);
                        }
                        Err(e) => eprintln!("[router] replica {k} respawn failed: {e}"),
                    }
                }
            }
        })
    };
    let report = net.run_until_drained()?;
    stop.store(true, Ordering::SeqCst);
    let _ = sup.join();
    fleet.shutdown();
    let s = &report.stats;
    println!(
        "serve-net: {} conns, {} requests ({} 2xx, {} 4xx incl {} 429, {} 5xx), \
         {} streams, {} tokens",
        s.conns, s.requests, s.s2xx, s.s4xx, s.s429, s.s5xx, s.streams, s.tokens
    );
    println!(
        "drain: {} finished, {} aborted, {} dropped queued, {} leaked sessions",
        report.drain.finished,
        report.drain.aborted,
        report.drain.dropped_queued,
        report.leaked_sessions
    );
    // Closing stdin is each worker's parent-death signal; they self-drain
    // (already drained over RPC — idempotent) and exit.
    let mut kids = match children.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    for child in kids.iter_mut() {
        drop(child.stdin.take());
    }
    for (k, child) in kids.iter_mut().enumerate() {
        let mut waited = 0u64;
        loop {
            match child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if waited < 3_000 => {
                    std::thread::sleep(Duration::from_millis(50));
                    waited += 50;
                }
                _ => {
                    eprintln!("[router] replica {k} ignored shutdown; killing");
                    let _ = child.kill();
                    let _ = child.wait();
                    break;
                }
            }
        }
    }
    drop(kids);
    if report.leaked_sessions > 0 {
        bail!("{} decode sessions leaked across drain", report.leaked_sessions);
    }
    Ok(())
}

/// `loadgen --addr HOST:PORT`: drive a listener with N concurrent
/// keep-alive clients, optional chaos, and report tail latencies.
/// `--scrape` brackets the run with `GET /metrics` on every target and
/// fails if the server's counter deltas disagree with what this client
/// observed (assumes loadgen is the only traffic source meanwhile).
fn cmd_loadgen(args: &Args) -> Result<()> {
    let addr_strs = args.get_all("addr");
    if addr_strs.is_empty() {
        bail!("--addr HOST:PORT required (repeatable; see `serve --listen`)");
    }
    let mut addrs: Vec<SocketAddr> = Vec::with_capacity(addr_strs.len());
    for s in &addr_strs {
        addrs.push(s.parse().map_err(|_| anyhow!("--addr: bad socket address {s:?}"))?);
    }
    let cfg = LoadGenConfig {
        clients: args.get_usize("clients", 4),
        requests_per_client: args.get_usize("requests", 4),
        prompt_len: args.get_usize("prompt-len", 8),
        max_new: args.get_usize("max-new", 8),
        vocab: args.get_usize("vocab", 64),
        timeout_ms: args.get_u64("timeout-ms", 30_000),
        chaos: chaos_arg(args)?,
        burst: args.flag("burst"),
        max_retries: args.get_usize("max-retries", 8),
        seed: args.get_u64("seed", 0),
        io_timeout_ms: args.get_u64("io-timeout-ms", 10_000),
    };
    let scrape = args.flag("scrape");
    let scrape_to = Duration::from_millis(cfg.io_timeout_ms.max(1));
    let before: Vec<(u64, u64)> = if scrape {
        let mut v = Vec::with_capacity(addrs.len());
        for a in &addrs {
            v.push(scrape_pair(*a, scrape_to)?);
        }
        v
    } else {
        Vec::new()
    };
    let addr_list =
        addrs.iter().map(|a| a.to_string()).collect::<Vec<_>>().join(", ");
    println!(
        "loadgen: {} clients x {} requests -> {addr_list} ({})",
        cfg.clients,
        cfg.requests_per_client,
        if cfg.burst { "burst" } else { "steady" }
    );
    let reports = hyena::net::client::run_loadgen_multi(&addrs, &cfg);
    if addrs.len() > 1 {
        for (a, rep) in addrs.iter().zip(&reports) {
            println!(
                "  [{a}] {} requests: {} ok, {} x 429, {} x 503, {} tokens",
                rep.requests, rep.ok, rep.rejected_429, rep.rejected_503, rep.tokens
            );
        }
    }
    let mut r = LoadReport::default();
    for rep in reports.iter().cloned() {
        r.merge(rep);
    }
    println!(
        "  {} requests: {} ok, {} x 429 ({} with Retry-After), {} x 503, \
         {} stream errors, {} io errors",
        r.requests,
        r.ok,
        r.rejected_429,
        r.retry_after_present,
        r.rejected_503,
        r.stream_errors,
        r.io_errors
    );
    if !cfg.chaos.is_off() {
        println!(
            "  chaos: {} disconnects, {} stalls, {} garbage injected \
             ({} rejected with 400)",
            r.disconnects_injected, r.stalls_injected, r.garbage_injected, r.garbage_rejected
        );
    }
    println!(
        "  {} tokens  ttfb p50 {:.2} / p99 {:.2} ms  decode p50 {:.3} / p99 {:.3} ms/token",
        r.tokens,
        r.ttfb_percentile(50.0),
        r.ttfb_percentile(99.0),
        r.ms_per_token_percentile(50.0),
        r.ms_per_token_percentile(99.0)
    );
    // Per-target, not aggregate: one compliant front end must not mask a
    // broken one when several `--addr` targets are driven round-robin.
    for (a, rep) in addrs.iter().zip(&reports) {
        if rep.rejected_429 > rep.retry_after_present {
            bail!(
                "target {a}: {} of {} 429 responses lacked Retry-After — \
                 backpressure contract broken",
                rep.rejected_429 - rep.retry_after_present,
                rep.rejected_429
            );
        }
    }
    if scrape {
        for ((a, rep), &(tok0, rej0)) in addrs.iter().zip(&reports).zip(&before) {
            let (tok1, rej1) = scrape_pair(*a, scrape_to)?;
            let d_tok = tok1.saturating_sub(tok0);
            let d_rej = rej1.saturating_sub(rej0);
            // Every 429 the server wrote reached a reading client (faults
            // are only injected into 200 streams), so this delta is exact
            // even under chaos.
            if d_rej != rep.rejected_429 as u64 {
                bail!(
                    "target {a}: hyena_admission_rejected_total advanced by {d_rej} \
                     but this client observed {} x 429 — /metrics disagrees with \
                     the wire",
                    rep.rejected_429
                );
            }
            // The server counts a token when it writes the event; a client
            // that hung up or stalled mid-stream (injected chaos) read
            // fewer. With chaos off the two are byte-for-byte equal.
            let tokens_ok = if cfg.chaos.is_off() {
                d_tok == rep.tokens as u64
            } else {
                d_tok >= rep.tokens as u64
            };
            if !tokens_ok {
                bail!(
                    "target {a}: hyena_tokens_generated_total advanced by {d_tok} \
                     but this client received {} token events{} — /metrics \
                     disagrees with the wire",
                    rep.tokens,
                    if cfg.chaos.is_off() { "" } else { " (chaos on: server may lead)" }
                );
            }
            println!(
                "  scrape [{a}]: tokens_generated +{d_tok} (client saw {}), \
                 admission_rejected +{d_rej} (client saw {}) — consistent",
                rep.tokens, rep.rejected_429
            );
        }
    }
    Ok(())
}

/// One `--scrape` sample: (tokens_generated_total, admission_rejected_total)
/// read off a target's `/metrics` aggregate (unlabeled) lines.
fn scrape_pair(addr: SocketAddr, timeout: Duration) -> Result<(u64, u64)> {
    let text = hyena::net::client::scrape_metrics(addr, timeout)
        .with_context(|| format!("--scrape: GET /metrics from {addr}"))?;
    let tok = hyena::net::client::scrape_counter(&text, "hyena_tokens_generated_total")
        .ok_or_else(|| anyhow!("--scrape: {addr} exposes no hyena_tokens_generated_total"))?;
    let rej = hyena::net::client::scrape_counter(&text, "hyena_admission_rejected_total")
        .ok_or_else(|| anyhow!("--scrape: {addr} exposes no hyena_admission_rejected_total"))?;
    Ok((tok, rej))
}

fn cmd_dump_filters(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let out = args.get_or("out", "results/filters.csv").to_string();
    let seed = args.get_u64("seed", 0);
    let (model, _) = load_model(args, &name, seed as i32)?;
    let h = model.dump_filters()?;
    let shape = h.shape().to_vec();
    let data = h.as_f32()?;
    let (n, d, l) = (shape[0], shape[1], shape[2]);
    let mut csv = String::from("order,channel,t,h\n");
    for o in 0..n {
        for c in 0..d.min(8) {
            for t in 0..l {
                csv.push_str(&format!("{o},{c},{t},{}\n", data[(o * d + c) * l + t]));
            }
        }
    }
    if let Some(parent) = Path::new(&out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&out, csv)?;
    println!("filters (N={n}, D={d}, L={l}) -> {out} (first 8 channels)");
    Ok(())
}
