//! `hyena` CLI — leader entrypoint for the coordinator.
//!
//! Subcommands:
//!   list                              list available artifacts
//!   train --model NAME [--steps N]    train on TinyPile (lm_*) or task data
//!   eval  --model NAME                held-out loss/ppl on TinyPile
//!   serve --model NAME [--requests N] run the batching server demo
//!   dump-filters --model NAME [--out F] write filter CSV (Fig. D.5)
//!   info  --model NAME                print manifest summary

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use hyena::coordinator::generation::Sampling;
use hyena::coordinator::server::{GenerateRequest, Server};
use hyena::coordinator::trainer::{eval_loss, Trainer};
use hyena::data::corpus::{generate, CorpusConfig};
use hyena::data::dataset::LmBatches;
use hyena::runtime::checkpoint::Checkpoint;
use hyena::runtime::{runtime, Manifest, ModelState};
use hyena::util::cli::Args;
use hyena::util::rng::Pcg;

fn main() -> Result<()> {
    let args = Args::parse(&["quiet", "greedy"]);
    match args.positional.first().map(String::as_str) {
        Some("list") => cmd_list(),
        Some("info") => cmd_info(&args),
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("dump-filters") => cmd_dump_filters(&args),
        _ => {
            eprintln!(
                "usage: hyena <list|info|train|eval|serve|dump-filters> \
                 [--model NAME] [--steps N] [--seed S]"
            );
            Ok(())
        }
    }
}

fn model_arg(args: &Args) -> Result<String> {
    args.get("model")
        .map(str::to_string)
        .ok_or_else(|| anyhow!("--model NAME required (see `hyena list`)"))
}

fn cmd_list() -> Result<()> {
    let dir = hyena::artifacts_dir();
    let mut names: Vec<String> = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().join("manifest.json").exists())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    for n in names {
        println!("{n}");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let m = Manifest::load(&hyena::artifact(&name))?;
    println!("name           {}", m.name);
    println!("family         {}", m.family());
    println!("params         {} tensors, {} elements", m.params.len(), m.numel());
    println!("batch x seqlen {} x {}", m.batch()?, m.seqlen()?);
    println!("train_step     {}", m.has_train_step);
    if let Some(f) = m.flops_per_step {
        println!("flops/step     {f:.3e}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let steps = args.get_u64("steps", 300);
    let seed = args.get_u64("seed", 0);
    println!("loading {name} (platform: {})", runtime().platform());
    let mut model = ModelState::load(&hyena::artifact(&name), seed as i32)?;
    if model.manifest.family() != "lm" {
        bail!("`hyena train` drives LM artifacts; use the examples/ for img");
    }
    let corpus = generate(&CorpusConfig { seed, ..Default::default() }, 400);
    println!(
        "TinyPile: {} train / {} val tokens",
        corpus.train.len(),
        corpus.val.len()
    );
    let b = model.manifest.batch()?;
    let l = model.manifest.seqlen()?;
    let vocab = model.manifest.vocab()?;
    if let Some(ckpt_path) = args.get("restore") {
        let ckpt = Checkpoint::load(std::path::Path::new(ckpt_path))?;
        model.step = ckpt.step;
        let params = ckpt.into_params(&model.manifest)?;
        model.set_params(&params)?;
        println!("restored checkpoint at step {}", model.step);
    }
    let mut batches = LmBatches::new(&corpus.train, b, l, seed).with_vocab(vocab);
    let mut trainer = Trainer::new(&mut model, move || batches.next_batch());
    trainer.quiet = args.flag("quiet");
    let report = trainer.run(steps)?;
    if let Some(save_path) = args.get("save").map(str::to_string) {
        let names: Vec<String> =
            model.manifest.params.iter().map(|p| p.name.clone()).collect();
        let tensors = model.params_host()?;
        let ckpt = Checkpoint {
            step: model.step,
            tensors: names.into_iter().zip(tensors).collect(),
        };
        ckpt.save(std::path::Path::new(&save_path))?;
        println!("saved checkpoint -> {save_path}");
    }
    println!(
        "done: loss {:.4}  {:.2} steps/s  {:.0} tok/s",
        report.final_loss, report.steps_per_s, report.tokens_per_s
    );
    let evals = LmBatches::eval_batches_vocab(&corpus.val, b, l, vocab);
    if !evals.is_empty() {
        let n = evals.len().min(4);
        let mut i = 0;
        let nll = eval_loss(
            &model,
            &mut || {
                let batch = evals[i].clone();
                i += 1;
                batch
            },
            n,
        )?;
        println!("val loss {:.4}  ppl {:.2}", nll, nll.exp());
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let seed = args.get_u64("seed", 0);
    let model = ModelState::load(&hyena::artifact(&name), seed as i32)?;
    let corpus = generate(&CorpusConfig { seed, ..Default::default() }, 400);
    let b = model.manifest.batch()?;
    let l = model.manifest.seqlen()?;
    let evals = LmBatches::eval_batches_vocab(&corpus.val, b, l, model.manifest.vocab()?);
    let n = evals.len().min(8);
    let mut i = 0;
    let nll = eval_loss(
        &model,
        &mut || {
            let batch = evals[i].clone();
            i += 1;
            batch
        },
        n,
    )?;
    println!(
        "{name}: val loss {:.4}  ppl {:.2} (untrained init unless restored)",
        nll,
        nll.exp()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let n_req = args.get_usize("requests", 16);
    let seed = args.get_u64("seed", 0);
    let man = Manifest::load(&hyena::artifact(&name))?;
    let l = man.seqlen()?;
    let vocab = man.vocab()?;
    let server = Server::start(hyena::artifact(&name), seed as i32, Duration::from_millis(20))?;
    println!("server up; firing {n_req} requests");
    let mut rng = Pcg::new(seed);
    let sampling = if args.flag("greedy") {
        Sampling::Greedy
    } else {
        Sampling::Temperature { t: 0.8, top_k: 16 }
    };
    let handles: Vec<_> = (0..n_req)
        .map(|_| {
            let prompt: Vec<i32> = (0..8).map(|_| rng.usize_below(vocab) as i32).collect();
            server.handle.submit(GenerateRequest {
                prompt,
                max_new: 16.min(l.saturating_sub(9)),
                sampling,
            })
        })
        .collect();
    let mut total = Duration::ZERO;
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.recv().map_err(|_| anyhow!("worker died"))??;
        total += resp.total_time;
        println!(
            "  req {i:>3}: {} tokens, queue {:?}, total {:?}, batch x{}",
            resp.tokens.len(),
            resp.queue_time,
            resp.total_time,
            resp.batch_occupancy
        );
    }
    println!("mean latency {:?}", total / n_req as u32);
    server.stop();
    Ok(())
}

fn cmd_dump_filters(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let out = args.get_or("out", "results/filters.csv").to_string();
    let seed = args.get_u64("seed", 0);
    let model = ModelState::load(&hyena::artifact(&name), seed as i32)?;
    let h = model.dump_filters()?;
    let shape = h.shape().to_vec();
    let data = h.as_f32()?;
    let (n, d, l) = (shape[0], shape[1], shape[2]);
    let mut csv = String::from("order,channel,t,h\n");
    for o in 0..n {
        for c in 0..d.min(8) {
            for t in 0..l {
                csv.push_str(&format!("{o},{c},{t},{}\n", data[(o * d + c) * l + t]));
            }
        }
    }
    if let Some(parent) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&out, csv)?;
    println!("filters (N={n}, D={d}, L={l}) -> {out} (first 8 channels)");
    Ok(())
}
