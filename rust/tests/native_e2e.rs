//! End-to-end tests of the native backend — no artifacts, no PJRT, no
//! Python. These exercise the acceptance path of the backend refactor:
//! train a tiny model a few steps (loss decreases), decode deterministically
//! through the coordinator, serve through the dynamic-batching server, and
//! round-trip a checkpoint — all with `HYENA_ARTIFACTS` absent.

use std::path::PathBuf;
use std::time::Duration;

use hyena::backend::native::{NativeConfig, NativeModel};
use hyena::backend::{self, Backend, BackendKind};
use hyena::coordinator::generation::{
    argmax, decode_batch, decode_batch_recompute, sample_token, Sampling,
};
use hyena::coordinator::server::{GenerateRequest, Server};
use hyena::coordinator::trainer::{eval_accuracy, Trainer};
use hyena::runtime::checkpoint::Checkpoint;
use hyena::tasks::recall::RecallTask;
use hyena::util::rng::Pcg;

fn native(name: &str, seed: i32) -> Box<dyn Backend> {
    // The path intentionally has no manifest.json: the native backend
    // resolves the built-in config by its final component.
    backend::load(BackendKind::Native, &PathBuf::from("artifacts").join(name), seed)
        .expect("native backend should need no artifacts")
}

#[test]
fn training_reduces_loss_without_artifacts() {
    let mut model = native("golden_tiny", 0);
    let task = RecallTask::new(16, 8, 2);
    let mut rng = Pcg::new(0);
    let fixed = task.sample_batch(&mut rng).to_tensors();
    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..60 {
        last = model.train_step(&fixed).unwrap();
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(last.is_finite() && first.is_finite());
    assert!(last < first, "loss did not drop on a fixed batch: {first} -> {last}");
    assert_eq!(model.step(), 60);
}

#[test]
fn threaded_training_matches_single_thread() {
    // The row-parallel engine partitions output rows and keeps per-row
    // arithmetic in serial order, so a 2-thread training run must reproduce
    // the 1-thread losses on the same seed (the 1e-5 bound of the issue is
    // met exactly).
    let cfg = NativeConfig::builtin("golden_tiny").unwrap();
    let mut one = NativeModel::new(cfg.clone(), 3).unwrap();
    let mut two = NativeModel::new(cfg, 3).unwrap();
    one.set_threads(1);
    two.set_threads(2);
    let (b, l, v) = (one.cfg.batch, one.cfg.seqlen, one.cfg.vocab);
    let mut rng = Pcg::new(3);
    let tokens: Vec<i32> = (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
    let targets: Vec<i32> = (0..b * l).map(|_| rng.usize_below(v) as i32).collect();
    let mask = vec![1.0f32; b * l];
    for step in 0..6 {
        let l1 = one.train_step(&tokens, &targets, &mask, b).unwrap();
        let l2 = two.train_step(&tokens, &targets, &mask, b).unwrap();
        assert!(
            (l1 - l2).abs() <= 1e-5,
            "thread count changed the loss at step {step}: {l1} vs {l2}"
        );
    }
    assert_eq!(one.params, two.params, "thread count changed the parameters");
}

#[test]
fn trainer_loop_and_accuracy_eval_run_natively() {
    let mut model = native("golden_tiny", 1);
    let task = RecallTask::new(16, 8, 2);
    let mut rng = Pcg::new(1);
    let mut src = {
        let task = task.clone();
        move || task.sample_batch(&mut rng).to_tensors()
    };
    let report = {
        let mut tr = Trainer::new(model.as_mut(), &mut src);
        tr.quiet = true;
        tr.log_every = 5;
        tr.run(12).unwrap()
    };
    assert_eq!(report.steps, 12);
    assert!(report.curve.len() >= 2);
    assert!(report.steps_per_s > 0.0);
    assert!(report.total_flops.unwrap() > 0.0);
    assert_eq!(report.tokens_seen, 12 * 2 * 16);
    let acc = eval_accuracy(model.as_ref(), &mut src, 4).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn greedy_decode_is_deterministic_across_fresh_models() {
    let a = native("golden_tiny", 0);
    let b = native("golden_tiny", 0);
    let mut rng_a = Pcg::new(9);
    let mut rng_b = Pcg::new(9);
    let prompt = vec![3i32, 5, 7];
    let out_a =
        decode_batch(a.as_ref(), &[prompt.clone()], &[6], Sampling::Greedy, &mut rng_a).unwrap();
    let out_b = decode_batch(b.as_ref(), &[prompt], &[6], Sampling::Greedy, &mut rng_b).unwrap();
    assert_eq!(out_a, out_b, "same seed must decode identically");
    assert_eq!(out_a[0].len(), 6);
}

#[test]
fn decode_is_pad_invariant_natively() {
    let model = native("golden_tiny", 0);
    let mut rng = Pcg::new(0);
    let prompt = vec![3i32, 5, 7];
    let solo =
        decode_batch(model.as_ref(), &[prompt.clone()], &[4], Sampling::Greedy, &mut rng).unwrap();
    let duo = decode_batch(
        model.as_ref(),
        &[prompt, vec![9i32, 1, 2, 6]],
        &[4, 4],
        Sampling::Greedy,
        &mut rng,
    )
    .unwrap();
    assert_eq!(solo[0], duo[0], "batch padding leaked across rows");
}

#[test]
fn streamed_decode_batch_matches_recompute_with_compaction() {
    // The session loop (prefill once, then O(L) steps) must emit exactly
    // the token streams of the full-recompute reference — including when
    // rows retire at different times (max_new staggering exercises the
    // session-level row compaction) and when streams cross the engine's
    // bucket boundary mid-generation (golden_tiny buckets at [8, 16]).
    let model = native("golden_tiny", 0);
    let prompts =
        vec![vec![3i32, 5, 7], vec![9i32, 1, 2, 6, 11, 4], vec![8i32, 8, 1, 13, 2]];
    let max_new = [2usize, 9, 5];
    let mut rng_a = Pcg::new(5);
    let mut rng_b = Pcg::new(5);
    let streamed =
        decode_batch(model.as_ref(), &prompts, &max_new, Sampling::Greedy, &mut rng_a).unwrap();
    let recomputed =
        decode_batch_recompute(model.as_ref(), &prompts, &max_new, Sampling::Greedy, &mut rng_b)
            .unwrap();
    assert_eq!(streamed, recomputed, "streamed sessions diverged from recompute");
    for (r, out) in streamed.iter().enumerate() {
        assert_eq!(out.len(), max_new[r], "row {r} emitted a wrong token count");
    }
    // Decode-session accounting flowed through the Backend surface: one
    // session per row, one streamed step per token after each row's first,
    // nothing live afterwards.
    let mem = model.mem_report().expect("native backend reports memory");
    assert_eq!(mem.decode_sessions_total, 3);
    assert_eq!(mem.decode_sessions_live, 0, "sessions leaked");
    let want_steps: usize = max_new.iter().map(|&m| m - 1).sum();
    assert_eq!(mem.decode_steps, want_steps as u64, "steps were recomputed, not streamed");
    assert_eq!(mem.decode_state_bytes, 0, "session state bytes leaked");
}

#[test]
fn streamed_decode_survives_param_updates_mid_session() {
    // A parameter update between steps makes the resident state stale; the
    // backend must transparently re-prefill from the session's tokens and
    // keep generating (token-identically vs a fresh recompute of the same
    // sequence under the new parameters).
    let mut model = native("golden_tiny", 0);
    let mut logits = Vec::new();
    let prompt = vec![4i32, 9, 2];
    let mut sess = model.decode_begin(&prompt, &mut logits).unwrap();
    let t0 = hyena::coordinator::generation::argmax(&logits);
    model.decode_step(&mut sess, t0, &mut logits).unwrap();
    let t1 = hyena::coordinator::generation::argmax(&logits);

    // Train one step: epoch bumps, resident histories go stale.
    let task = RecallTask::new(16, 8, 2);
    let mut rng = Pcg::new(2);
    let batch = task.sample_batch(&mut rng).to_tensors();
    model.train_step(&batch).unwrap();

    model.decode_step(&mut sess, t1, &mut logits).unwrap();
    let t2 = hyena::coordinator::generation::argmax(&logits);
    model.decode_end(sess);

    // Reference under the new parameters: the same sequence recomputed.
    let seq = vec![prompt[0], prompt[1], prompt[2], t0, t1];
    let v = model.manifest().vocab().unwrap();
    let full = model.infer(&seq, 1, seq.len()).unwrap();
    let wf = full.as_f32().unwrap();
    let want = hyena::coordinator::generation::argmax(
        &wf[(seq.len() - 1) * v..seq.len() * v],
    );
    assert_eq!(t2, want, "stale-state rebuild diverged from recompute");
}

#[test]
fn server_round_trip_native() {
    let server = Server::start_kind(
        BackendKind::Native,
        PathBuf::from("artifacts/golden_tiny"),
        0,
        Duration::from_millis(5),
        None,
        None,
        None,
    )
    .unwrap();
    let handles: Vec<_> = (0..4)
        .map(|i| {
            server.handle.submit(GenerateRequest {
                prompt: vec![1 + i, 2, 3],
                max_new: 3,
                sampling: Sampling::Greedy,
                deadline: None,
                trace_id: 0,
            })
        })
        .collect();
    for h in handles {
        let resp = h.recv().unwrap().unwrap();
        assert_eq!(resp.tokens.len(), 3);
        assert!(resp.batch_occupancy >= 1);
        // golden_tiny (L = 16) buckets at [8, 16]; a 3-token prompt with a
        // 3-token budget must be served from the small bucket, not full-pad.
        assert_eq!(resp.bucket_len, 8, "short request fell back to full-pad");
    }
    // The serve report must expose the workspace accounting.
    let mem = server.handle.mem_report().expect("native worker reports memory");
    assert!(mem.serve_forwards >= 4);
    assert_eq!(mem.bucket_lens, vec![8, 16]);
    assert!(mem.bucket_hits[0] >= 4, "bucket hits not recorded: {:?}", mem.bucket_hits);
    assert!(mem.serve_arena_hiwater_bytes > 0);
    server.stop();
}

#[test]
fn server_batched_rounds_match_single_session_greedy_streams() {
    // The server's token round is now one `decode_step_batch` call over
    // every live session. Under greedy sampling the responses must be
    // token-identical to decoding each prompt alone through the serial
    // session path — batching changes wall-clock shape, never tokens.
    let server = Server::start_kind(
        BackendKind::Native,
        PathBuf::from("artifacts/golden_tiny"),
        0,
        Duration::from_millis(5),
        None,
        None,
        None,
    )
    .unwrap();
    let prompts: Vec<Vec<i32>> =
        vec![vec![1, 2, 3], vec![4, 5], vec![6, 7, 8, 9], vec![10, 11, 1]];
    let max_new = 5usize;
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| {
            server.handle.submit(GenerateRequest {
                prompt: p.clone(),
                max_new,
                sampling: Sampling::Greedy,
                deadline: None,
                trace_id: 0,
            })
        })
        .collect();
    let responses: Vec<Vec<i32>> =
        handles.into_iter().map(|h| h.recv().unwrap().unwrap().tokens).collect();
    // Every generated token beyond a request's first came from a streamed
    // step, and every round went through the batched entry point.
    let mem = server.handle.mem_report().expect("native worker reports memory");
    assert_eq!(mem.decode_steps, (prompts.len() * (max_new - 1)) as u64);
    assert!(mem.decode_step_batches >= 1, "server rounds did not use decode_step_batch");
    assert_eq!(mem.decode_step_batch_rows, mem.decode_steps);
    assert_eq!(mem.decode_sessions_live, 0);
    server.stop();
    // Serial single-request reference on a fresh model (greedy ⇒ rng-free).
    let model = native("golden_tiny", 0);
    let mut rng = Pcg::new(0);
    for (p, got) in prompts.iter().zip(&responses) {
        let want =
            decode_batch(model.as_ref(), &[p.clone()], &[max_new], Sampling::Greedy, &mut rng)
                .unwrap();
        assert_eq!(got, &want[0], "batched server stream diverged for prompt {p:?}");
    }
}

#[test]
fn server_routes_mixed_lengths_to_their_buckets() {
    let server = Server::start_kind(
        BackendKind::Native,
        PathBuf::from("artifacts/golden_tiny"),
        0,
        Duration::from_millis(5),
        None,
        None,
        None,
    )
    .unwrap();
    // Terminal lengths 5 and 14 → buckets 8 and 16 of golden_tiny.
    let short = server.handle.submit(GenerateRequest {
        prompt: vec![1, 2, 3],
        max_new: 2,
        sampling: Sampling::Greedy,
        deadline: None,
        trace_id: 0,
    });
    let long = server.handle.submit(GenerateRequest {
        prompt: vec![1; 10],
        max_new: 4,
        sampling: Sampling::Greedy,
        deadline: None,
        trace_id: 0,
    });
    let short = short.recv().unwrap().unwrap();
    let long = long.recv().unwrap().unwrap();
    assert_eq!(short.bucket_len, 8);
    assert_eq!(long.bucket_len, 16);
    assert_eq!(short.tokens.len(), 2);
    assert_eq!(long.tokens.len(), 4);
    server.stop();
}

#[test]
fn bucketed_decode_matches_full_window_decode() {
    // Greedy decoding through the bucketed infer path must emit the same
    // token stream as decoding with every round padded to the full window
    // (the pre-bucketing behaviour, reproduced here with a 1-level ladder).
    let bucketed = native("golden_tiny", 0);
    let mut fullpad = native("golden_tiny", 0);
    fullpad.set_serve_buckets(1).unwrap();
    assert_eq!(fullpad.serve_buckets(), vec![16]);
    assert_eq!(bucketed.serve_buckets(), vec![8, 16]);
    let mut rng_a = Pcg::new(11);
    let mut rng_b = Pcg::new(11);
    let prompt = vec![4i32, 9, 2];
    let a = decode_batch(bucketed.as_ref(), &[prompt.clone()], &[10], Sampling::Greedy, &mut rng_a)
        .unwrap();
    let b = decode_batch(fullpad.as_ref(), &[prompt], &[10], Sampling::Greedy, &mut rng_b).unwrap();
    assert_eq!(a, b, "bucketed decode diverged from the full-pad decode");
    assert_eq!(a[0].len(), 10);
}

#[test]
fn serve_path_steady_state_is_zero_alloc() {
    // Through the whole Backend surface: repeated same-shape requests must
    // stop growing the serving workspace (allocs and high-water both flat).
    let model = native("golden_tiny", 0);
    let tokens: Vec<i32> = (1..=6).collect();
    // Warm until the accounting settles (spectra build + arena growth).
    let mut warm = None;
    for _ in 0..10 {
        model.infer(&tokens, 1, 6).unwrap();
        let mem = model.mem_report().unwrap();
        let snap = (mem.serve_arena_allocs, mem.serve_arena_hiwater_bytes);
        if warm == Some(snap) {
            break;
        }
        warm = Some(snap);
    }
    let warm = warm.unwrap();
    for _ in 0..12 {
        model.infer(&tokens, 1, 6).unwrap();
    }
    let mem = model.mem_report().unwrap();
    assert_eq!(
        (mem.serve_arena_allocs, mem.serve_arena_hiwater_bytes),
        warm,
        "steady-state serving kept allocating"
    );
    assert!(mem.serve_spec_bytes > 0, "filter spectra should be cached");
}

#[test]
fn checkpoint_round_trips_through_the_backend_trait() {
    let mut src = native("native_micro", 4);
    // A couple of steps so optimizer-visible params differ from init.
    let task = RecallTask::new(8, 8, 2);
    let mut rng = Pcg::new(4);
    let batch = task.sample_batch(&mut rng).to_tensors();
    for _ in 0..3 {
        src.train_step(&batch).unwrap();
    }
    let names: Vec<String> =
        src.manifest().params.iter().map(|p| p.name.clone()).collect();
    let ckpt = Checkpoint {
        step: src.step(),
        tensors: names.into_iter().zip(src.params_host().unwrap()).collect(),
    };
    let path = std::env::temp_dir().join("hyena_native_e2e_ckpt.bin");
    ckpt.save(&path).unwrap();

    let mut dst = native("native_micro", 99);
    let loaded = Checkpoint::load(&path).unwrap();
    dst.set_step(loaded.step);
    let params = loaded.into_params(dst.manifest()).unwrap();
    dst.set_params(&params).unwrap();
    assert_eq!(dst.step(), 3);

    // Restored model must agree with the source exactly.
    let mut rng2 = Pcg::new(5);
    let probe = decode_batch(src.as_ref(), &[vec![1, 2, 3]], &[4], Sampling::Greedy, &mut rng2)
        .unwrap();
    let probe2 = decode_batch(dst.as_ref(), &[vec![1, 2, 3]], &[4], Sampling::Greedy, &mut rng2)
        .unwrap();
    assert_eq!(probe, probe2);
}

#[test]
fn longctx_chunked_prefill_is_bitwise_with_bucketed_infer_at_full_bucket() {
    // The exactness tentpole at the Backend surface: a prompt exactly one
    // compiled window long prefills through the chunked path (one chunk,
    // empty carry, the full bucket's FFT plan), and its last-position
    // logits are bit-for-bit what the monolithic bucketed forward of an
    // identically seeded model produces.
    let mut chunked = native("golden_tiny", 0);
    chunked.set_max_context(64).unwrap();
    assert_eq!(chunked.decode_window(), 64);
    let plain = native("golden_tiny", 0);
    let l = plain.manifest().seqlen().unwrap();
    let v = plain.manifest().vocab().unwrap();
    let prompt: Vec<i32> = (0..l as i32).map(|i| i % 29).collect();
    let mut logits = Vec::new();
    let sess = chunked.decode_begin(&prompt, &mut logits).unwrap();
    chunked.decode_end(sess);
    let mem = chunked.mem_report().unwrap();
    assert_eq!(mem.prefill_chunked, 1, "a window-length prompt must prefill chunked");
    assert_eq!(mem.prefill_chunks, 1);
    let mono = plain.infer(&prompt, 1, l).unwrap();
    let mf = mono.as_f32().unwrap();
    let want = &mf[(l - 1) * v..l * v];
    assert_eq!(logits.len(), v);
    for (ch, (a, b)) in logits.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "logit {ch}: chunked {a} vs monolithic {b}");
    }
}

#[test]
fn longctx_decode_from_chunked_prefill_survives_epoch_bump() {
    // A prompt longer than the compiled window prefills through the
    // overlap-save chunks and decodes greedily; a parameter update lands
    // mid-session (epoch bump — the resident state goes stale), forcing a
    // transparent re-prefill that must itself take the chunked path; every
    // step stays token-identical to recomputing the growing prefix from
    // scratch through the (also chunked) single-row infer.
    let mut model = native("golden_tiny", 0);
    model.set_max_context(64).unwrap();
    let v = model.manifest().vocab().unwrap();
    let prompt: Vec<i32> = (0..24).map(|i| 1 + i % 13).collect();
    let mut logits = Vec::new();
    let mut sess = model.decode_begin(&prompt, &mut logits).unwrap();
    let mut seq = prompt.clone();
    let mut next = argmax(&logits);
    for step in 0..6 {
        if step == 3 {
            let task = RecallTask::new(16, 8, 2);
            let mut rng = Pcg::new(6);
            let batch = task.sample_batch(&mut rng).to_tensors();
            model.train_step(&batch).unwrap();
        }
        model.decode_step(&mut sess, next, &mut logits).unwrap();
        seq.push(next);
        let full = model.infer(&seq, 1, seq.len()).unwrap();
        let wf = full.as_f32().unwrap();
        let want = argmax(&wf[(seq.len() - 1) * v..seq.len() * v]);
        next = argmax(&logits);
        assert_eq!(next, want, "step {step} diverged from the chunked recompute");
    }
    model.decode_end(sess);
    let mem = model.mem_report().unwrap();
    // The begin and the stale rebuild both prefilled chunked (plus the
    // six single-row recomputes above).
    assert!(mem.prefill_chunked >= 2, "stale rebuild skipped the chunked path");
    assert_eq!(mem.decode_sessions_live, 0);
}

#[test]
fn sorted_rounds_keep_token_streams_identical() {
    // decode_batch hands the engine each round's rows sorted by history
    // length. Under temperature sampling the rng stream is the sharpest
    // invariant: tokens must match a serial reference that steps and
    // samples strictly in row order, on prompts whose length order differs
    // from their row order.
    let model = native("golden_tiny", 0);
    let prompts = vec![
        vec![1i32, 2, 3, 4, 5, 6],
        vec![7i32, 8],
        vec![9i32, 10, 11, 12],
        vec![13i32, 1, 2],
    ];
    let n = prompts.len();
    let max_new = vec![5usize; n];
    let sampling = Sampling::Temperature { t: 0.8, top_k: 4 };
    let mut rng_a = Pcg::new(21);
    let batched =
        decode_batch(model.as_ref(), &prompts, &max_new, sampling, &mut rng_a).unwrap();

    // Serial reference: same seed, prefill then per-round stepping and
    // sampling in row order — the rng order decode_batch promises.
    let mut rng_b = Pcg::new(21);
    let mut logits = Vec::new();
    let mut sessions = Vec::new();
    let mut out: Vec<Vec<i32>> = vec![Vec::new(); n];
    for r in 0..n {
        let sess = model.decode_begin(&prompts[r], &mut logits).unwrap();
        out[r].push(sample_token(&logits, sampling, &mut rng_b));
        sessions.push(sess);
    }
    for _round in 1..5 {
        for r in 0..n {
            let tok = *out[r].last().unwrap();
            model.decode_step(&mut sessions[r], tok, &mut logits).unwrap();
            out[r].push(sample_token(&logits, sampling, &mut rng_b));
        }
    }
    for sess in sessions {
        model.decode_end(sess);
    }
    assert_eq!(batched, out, "round shaping changed a token stream");
}

#[test]
fn longctx_server_admits_past_the_compiled_window() {
    // The server, started with a --max-context window, must admit prompts
    // beyond the compiled shape, prefill them through the chunked path,
    // and expose the long-context accounting in its serve report.
    let server = Server::start_kind(
        BackendKind::Native,
        PathBuf::from("artifacts/golden_tiny"),
        0,
        Duration::from_millis(5),
        None,
        None,
        Some(64),
    )
    .unwrap();
    let long = server.handle.submit(GenerateRequest {
        prompt: (0..24).map(|i| 1 + i % 13).collect(),
        max_new: 4,
        sampling: Sampling::Greedy,
        deadline: None,
        trace_id: 0,
    });
    let short = server.handle.submit(GenerateRequest {
        prompt: vec![1, 2, 3],
        max_new: 3,
        sampling: Sampling::Greedy,
        deadline: None,
        trace_id: 0,
    });
    let long = long.recv().unwrap().unwrap();
    let short = short.recv().unwrap().unwrap();
    assert_eq!(long.tokens.len(), 4);
    assert_eq!(short.tokens.len(), 3);
    // Long prompts route past every bucket to the ladder's largest plan.
    assert_eq!(long.bucket_len, 16);
    assert_eq!(short.bucket_len, 8, "short prompts must keep their bucket");
    let mem = server.handle.mem_report().expect("native worker reports memory");
    assert_eq!(mem.max_context, 64);
    assert_eq!(mem.ext_bucket_lens, vec![32, 64]);
    assert!(mem.prefill_chunked >= 1, "the long prompt did not prefill chunked");
    assert!(mem.prefill_chunk_bytes > 0);
    assert_eq!(mem.decode_sessions_live, 0);
    server.stop();
}

#[test]
fn longctx_64k_window_keeps_prefill_bytes_o_chunk() {
    // The memory acceptance gate of ISSUE 6 at the Backend surface: with a
    // 64K window, quadrupling the prompt must not move the prefill
    // activation high-water — the chunked path's working set is O(chunk),
    // not O(L).
    let mut model = native("golden_tiny", 0);
    model.set_max_context(1 << 16).unwrap();
    fn prefill(model: &dyn Backend, n: usize, logits: &mut Vec<f32>) {
        let prompt: Vec<i32> = (0..n as i32).map(|i| i % 31).collect();
        let sess = model.decode_begin(&prompt, logits).unwrap();
        model.decode_end(sess);
    }
    let mut logits = Vec::new();
    prefill(model.as_ref(), 4096, &mut logits);
    let b1 = model.mem_report().unwrap().prefill_chunk_bytes;
    prefill(model.as_ref(), 16384, &mut logits);
    let mem = model.mem_report().unwrap();
    assert!(b1 > 0);
    assert_eq!(mem.prefill_chunk_bytes, b1, "prefill bytes grew with prompt length");
    assert_eq!(mem.max_context, 1 << 16);
    assert_eq!(mem.ext_bucket_lens.last(), Some(&(1 << 16)));
    assert_eq!(mem.prefill_chunked, 2);
    assert_eq!(mem.prefill_chunks, (4096usize.div_ceil(16) + 16384usize.div_ceil(16)) as u64);
}

#[test]
fn pjrt_backend_fails_cleanly_under_the_stub() {
    // With the vendored xla stub linked, the pjrt path must surface a clean
    // error (not a panic), pointing the user at the native backend.
    let err = backend::load(
        BackendKind::Pjrt,
        &PathBuf::from("artifacts/golden_tiny"),
        0,
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(!msg.is_empty());
}

#[test]
fn server_deadlines_expire_cleanly() {
    // Deadline hardening: an expired request must reply with an error (never
    // hang, never panic) and leave zero session state behind, whether it
    // dies in the queue, at admission, or mid-decode.
    let server = Server::start_kind(
        BackendKind::Native,
        PathBuf::from("artifacts/golden_tiny"),
        0,
        Duration::from_millis(5),
        None,
        None,
        None,
    )
    .unwrap();
    // (a) Already expired on arrival (deadline = now): deterministically
    // swept before the engine ever sees it — zero tokens, a deadline error.
    let h = server.handle.submit(GenerateRequest {
        prompt: vec![1, 2, 3],
        max_new: 4,
        sampling: Sampling::Greedy,
        deadline: Some(Duration::ZERO),
        trace_id: 0,
    });
    let err = h.recv().unwrap().expect_err("expired deadline must not generate");
    assert!(
        format!("{err:#}").contains("deadline exceeded"),
        "unexpected error: {err:#}"
    );
    let begun_before = server.handle.mem_report().unwrap().decode_sessions_total;
    // (b) Tight deadlines racing a healthy request: every reply arrives
    // (completion or a deadline error — wall clock decides which), the
    // healthy request is token-complete, and nothing leaks either way.
    let healthy = server.handle.submit(GenerateRequest {
        prompt: vec![4, 5, 6],
        max_new: 3,
        sampling: Sampling::Greedy,
        deadline: None,
        trace_id: 0,
    });
    let tight: Vec<_> = (0..4)
        .map(|i| {
            server.handle.submit(GenerateRequest {
                prompt: vec![1 + i, 2, 3],
                max_new: 8,
                sampling: Sampling::Greedy,
                deadline: Some(Duration::from_millis(1 + i as u64 % 2)),
            })
        })
        .collect();
    assert_eq!(healthy.recv().unwrap().unwrap().tokens.len(), 3);
    for h in tight {
        match h.recv().expect("worker died under deadline load") {
            Ok(resp) => assert!(resp.tokens.len() <= 8),
            Err(e) => assert!(
                format!("{e:#}").contains("deadline exceeded"),
                "unexpected error: {e:#}"
            ),
        }
    }
    let mem = server.handle.mem_report().unwrap();
    // (a) never began a session; (b) began up to 5 and retired them all.
    assert!(mem.decode_sessions_total >= begun_before);
    assert_eq!(mem.decode_sessions_live, 0, "deadline retirement leaked sessions");
    server.stop();
}
