//! Loopback end-to-end tests for the network serving front end: real
//! sockets against a real engine (`golden_tiny`, native backend), covering
//! the resilience gates — greedy byte-identity with the in-process path,
//! deterministic 429 + Retry-After under overload, chaos disconnects
//! leaking nothing, queue-deadline expiry over HTTP, malformed-request
//! rejection, and graceful drain with zero leaked sessions.
//!
//! Every test binds port 0 and uses the per-server drain flag
//! (`trigger_drain`/`finish`), never process-global signals — parallel
//! tests must not drain each other.

use std::path::PathBuf;
use std::time::Duration;

use hyena::backend::BackendKind;
use hyena::coordinator::generation::Sampling;
use hyena::coordinator::server::{GenerateRequest, Server};
use hyena::net::client::{generate_body, run_loadgen, Fault, HttpClient, LoadGenConfig};
use hyena::net::server::NetServer;
use hyena::net::{ChaosConfig, NetConfig};

/// Engine + listener on a free loopback port, logs off.
fn start_stack(tweak: impl FnOnce(&mut NetConfig)) -> (Server, NetServer) {
    let server = Server::start_kind(
        BackendKind::Native,
        PathBuf::from("artifacts/golden_tiny"),
        0,
        Duration::from_millis(5),
        None,
        None,
        None,
    )
    .unwrap();
    let mut cfg = NetConfig {
        addr: "127.0.0.1:0".into(),
        conn_threads: 8,
        quiet: true,
        ..NetConfig::default()
    };
    tweak(&mut cfg);
    let net = NetServer::start(server.handle.clone(), cfg).unwrap();
    (server, net)
}

/// Keep the engine busy long enough that ticketed HTTP submissions queue
/// behind it (FIFO) instead of completing instantly — the lever that makes
/// overload and queue-deadline behaviour deterministic on a tiny model.
fn flood(server: &Server, n: usize) -> Vec<std::sync::mpsc::Receiver<anyhow::Result<hyena::coordinator::server::GenerateResponse>>> {
    (0..n)
        .map(|i| {
            server.handle.submit(GenerateRequest {
                prompt: vec![1 + (i % 11) as i32, 2, 3],
                max_new: 8,
                sampling: Sampling::Greedy,
                deadline: None,
                trace_id: 0,
            })
        })
        .collect()
}

#[test]
fn loopback_streams_match_in_process_greedy() {
    let (server, net) = start_stack(|_| {});
    let addr = net.addr();
    let prompts: Vec<Vec<i32>> =
        vec![vec![1, 2, 3], vec![4, 5], vec![6, 7, 8, 9], vec![10, 11, 1]];
    // Concurrent keep-alive clients, two sequential requests each — the
    // second request re-uses the socket, so keep-alive is exercised too.
    let mut joins = Vec::new();
    for (i, p) in prompts.iter().cloned().enumerate() {
        joins.push(std::thread::spawn(move || {
            let mut c = HttpClient::connect(addr, Duration::from_secs(10)).unwrap();
            let mut outs = Vec::new();
            for _ in 0..2 {
                let out = c.generate_stream(&generate_body(&p, 5, 0), Fault::None).unwrap();
                assert_eq!(out.status, 200, "stream rejected: {:?}", out.reject);
                assert!(out.done.is_some(), "stream ended without done: {:?}", out.error);
                outs.push(out.tokens.clone());
            }
            (i, outs)
        }));
    }
    let mut got: Vec<(usize, Vec<Vec<i32>>)> =
        joins.into_iter().map(|j| j.join().unwrap()).collect();
    got.sort_by_key(|g| g.0);
    // Greedy is rng-free and batching is token-invariant, so the network
    // streams must be byte-identical to the in-process blocking path on
    // the very same engine.
    for (i, outs) in got {
        let want = server
            .handle
            .generate(GenerateRequest {
                prompt: prompts[i].clone(),
                max_new: 5,
                sampling: Sampling::Greedy,
                deadline: None,
                trace_id: 0,
            })
            .unwrap();
        for o in outs {
            assert_eq!(o, want.tokens, "network stream diverged for prompt {:?}", prompts[i]);
        }
    }
    let report = net.finish().unwrap();
    assert_eq!(report.leaked_sessions, 0);
    assert!(report.stats.streams >= 8, "stats: {:?}", report.stats);
    assert_eq!(report.stats.s429, 0);
    server.stop();
}

#[test]
fn overload_burst_gets_429_with_retry_after() {
    // conn_threads must exceed admit_cap + extras, or the surplus would
    // queue at the connection dispatcher (503 path) instead of reaching
    // admission control (429 path).
    let (server, net) = start_stack(|c| {
        c.queue_cap = 1;
        c.conn_threads = 16;
    });
    let addr = net.addr();
    let admit_cap = server.handle.capacity() + 1;
    // While the flood holds the engine, ticketed HTTP submissions sit in
    // the queue holding their admission slots: exactly `admit_cap` slots
    // exist, so of `admit_cap + extras` concurrent posts, exactly `extras`
    // must bounce with 429 — deterministically, regardless of ordering.
    let flood_rx = flood(&server, 4000);
    let extras = 4usize;
    let joins: Vec<_> = (0..admit_cap + extras)
        .map(|_| {
            std::thread::spawn(move || {
                let mut c = HttpClient::connect(addr, Duration::from_secs(30)).unwrap();
                c.generate_stream(&generate_body(&[1, 2, 3], 4, 0), Fault::None).unwrap()
            })
        })
        .collect();
    let outs: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let ok = outs.iter().filter(|o| o.status == 200 && o.done.is_some()).count();
    let rejected: Vec<_> = outs.iter().filter(|o| o.status == 429).collect();
    assert_eq!(ok, admit_cap, "admitted requests must all complete");
    assert_eq!(rejected.len(), extras, "overload must bounce the surplus");
    for r in &rejected {
        let resp = r.reject.as_ref().expect("429 carries a fixed body");
        assert!(
            resp.header("retry-after").is_some(),
            "429 without Retry-After: {:?}",
            resp.headers
        );
        assert!(resp.keep_alive, "429 must not cost the connection");
    }
    for rx in flood_rx {
        rx.recv().unwrap().unwrap();
    }
    let report = net.finish().unwrap();
    assert_eq!(report.leaked_sessions, 0);
    assert_eq!(report.stats.s429, extras as u64);
    server.stop();
}

#[test]
fn queue_deadline_expires_over_http() {
    let (server, net) = start_stack(|_| {});
    let addr = net.addr();
    // The flood keeps capacity full, so a 1 ms request budget expires in
    // the queue: the stream must open, carry no tokens, and terminate with
    // an explicit deadline error event — never hang, never a silent close.
    let flood_rx = flood(&server, 2000);
    let mut c = HttpClient::connect(addr, Duration::from_secs(30)).unwrap();
    let out = c.generate_stream(&generate_body(&[1, 2, 3], 8, 1), Fault::None).unwrap();
    assert_eq!(out.status, 200);
    assert!(out.tokens.is_empty(), "expired request generated {:?}", out.tokens);
    let err = out.error.expect("expired stream must end with an error event");
    let msg = err.get("message").and_then(|m| m.as_str()).unwrap_or_default().to_string();
    assert!(msg.contains("deadline exceeded"), "unexpected error payload: {err:?}");
    for rx in flood_rx {
        rx.recv().unwrap().unwrap();
    }
    let report = net.finish().unwrap();
    assert_eq!(report.leaked_sessions, 0);
    server.stop();
}

#[test]
fn malformed_requests_get_400_not_a_wedge() {
    let (server, net) = start_stack(|_| {});
    let addr = net.addr();
    // Framing garbage: not JSON at all. The server answers 400 and closes
    // (byte sync with the connection is lost).
    let mut c = HttpClient::connect(addr, Duration::from_secs(10)).unwrap();
    c.send_raw(b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 9\r\n\r\nnot json!")
        .unwrap();
    let r = c.read_response().unwrap();
    assert_eq!(r.status, 400);
    assert!(!r.keep_alive, "a framing error must cost the connection");
    // Well-framed JSON, wrong schema: still 400, with an error body.
    let mut c = HttpClient::connect(addr, Duration::from_secs(10)).unwrap();
    let r = c.post("/generate", r#"{"prompt":"nope"}"#).unwrap();
    assert_eq!(r.status, 400);
    assert!(r.json().is_some_and(|j| j.get("error").is_some()));
    // Routing: health and mem answer, unknown paths 404, wrong methods 405.
    let mut c = HttpClient::connect(addr, Duration::from_secs(10)).unwrap();
    assert_eq!(c.get("/healthz").unwrap().status, 200);
    assert_eq!(c.get("/mem").unwrap().status, 200);
    assert_eq!(c.get("/nope").unwrap().status, 404);
    assert_eq!(c.get("/generate").unwrap().status, 405);
    // The engine never saw any of it — and still serves.
    let out = c.generate_stream(&generate_body(&[1, 2, 3], 3, 0), Fault::None).unwrap();
    assert_eq!(out.status, 200);
    assert_eq!(out.tokens.len(), 3);
    let report = net.finish().unwrap();
    assert_eq!(report.leaked_sessions, 0);
    server.stop();
}

#[test]
fn chaos_disconnects_leak_no_sessions() {
    let (server, net) = start_stack(|c| c.token_buf = 4);
    let addr = net.addr();
    let chaos = ChaosConfig::parse("disconnect:0.6,garbage:0.2,seed:11").unwrap();
    let cfg = LoadGenConfig {
        clients: 4,
        requests_per_client: 4,
        prompt_len: 5,
        max_new: 8,
        vocab: 32,
        timeout_ms: 5_000,
        chaos,
        seed: 3,
        ..LoadGenConfig::default()
    };
    let report = run_loadgen(addr, &cfg);
    assert_eq!(report.requests, 16);
    assert!(
        report.disconnects_injected + report.garbage_injected > 0,
        "chaos config injected nothing: {report:?}"
    );
    // Every injected garbage request that got an answer got 400.
    assert!(report.garbage_rejected <= report.garbage_injected);
    // The gate: mid-stream hangups and malformed bytes must leave zero
    // session state behind once the wire goes quiet.
    let net_report = net.finish().unwrap();
    assert_eq!(
        net_report.leaked_sessions, 0,
        "chaos run leaked sessions: {:?}",
        net_report.mem.map(|m| m.decode_sessions_live)
    );
    server.stop();
}

#[test]
fn drain_finishes_live_streams_and_leaks_nothing() {
    let (server, net) = start_stack(|c| c.drain_ms = 2_000);
    let addr = net.addr();
    // A stream in flight when the drain order lands must still get a
    // terminal event (done, or an explicit drain error) — not a hang.
    let j = std::thread::spawn(move || {
        let mut c = HttpClient::connect(addr, Duration::from_secs(10)).unwrap();
        c.generate_stream(&generate_body(&[1, 2, 3, 4], 8, 0), Fault::None).unwrap()
    });
    std::thread::sleep(Duration::from_millis(30));
    net.trigger_drain();
    let report = net.finish().unwrap();
    let out = j.join().unwrap();
    assert_eq!(out.status, 200);
    assert!(
        out.done.is_some() || out.error.is_some(),
        "draining stream ended without a terminal event"
    );
    assert_eq!(report.leaked_sessions, 0);
    // Post-drain the listener is gone: new connections must be refused,
    // not black-holed (connect or first I/O errors out promptly).
    let dead = HttpClient::connect(addr, Duration::from_millis(500))
        .and_then(|mut c| c.get("/healthz"));
    assert!(dead.is_err(), "listener still serving after drain");
    server.stop();
}
