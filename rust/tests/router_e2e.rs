//! End-to-end tests for the replica-parallel router (`net::router`): real
//! framed-RPC sockets between an in-process [`FleetHandle`] and N
//! in-process worker engines (`golden_tiny`, native backend), covering the
//! fleet gates — greedy byte-identity with the in-process path, session
//! affinity under interleaved decode, replica-kill failover leaking
//! nothing, epoch-synchronized parameter broadcast (stale replicas held
//! out of the candidate set), and fleet-wide drain finishing live streams.
//!
//! Replicas here are threads, not child processes (`ReplicaServer` around
//! a local engine) — `ReplicaServer::kill` severs every connection
//! abortively, which is indistinguishable on the wire from a worker
//! process dying. The spawned-process path is exercised by
//! `benches/native_router.rs` and `scripts/check.sh router-smoke`.

use std::path::PathBuf;
use std::time::Duration;

use hyena::backend::BackendKind;
use hyena::coordinator::generation::Sampling;
use hyena::coordinator::server::{
    AdmitError, Engine, GenerateRequest, Server, StreamEvent,
};
use hyena::net::router::{FleetConfig, FleetHandle, ReplicaServer};

/// One worker: engine + framed-RPC endpoint on a free loopback port.
fn start_replica() -> (Server, ReplicaServer) {
    let server = Server::start_kind(
        BackendKind::Native,
        PathBuf::from("artifacts/golden_tiny"),
        0,
        Duration::from_millis(5),
        None,
        None,
        None,
    )
    .unwrap();
    let rs = ReplicaServer::start(server.handle.clone(), "127.0.0.1:0").unwrap();
    (server, rs)
}

/// N identical workers plus the fleet front. Fast probes so mark-down /
/// mark-up transitions land within test timeouts.
fn start_fleet(n: usize) -> (Vec<(Server, ReplicaServer)>, FleetHandle) {
    let workers: Vec<_> = (0..n).map(|_| start_replica()).collect();
    let addrs: Vec<_> = workers.iter().map(|(_, rs)| rs.addr()).collect();
    let fleet = FleetHandle::connect(
        &addrs,
        FleetConfig { probe_ms: 40, quiet: true, ..FleetConfig::default() },
    )
    .unwrap();
    (workers, fleet)
}

fn greedy(prompt: &[i32], max_new: usize) -> GenerateRequest {
    GenerateRequest {
        prompt: prompt.to_vec(),
        max_new,
        sampling: Sampling::Greedy,
        deadline: None,
        trace_id: 0,
    }
}

/// Drain one routed stream to its terminal event.
fn collect(
    rx: &std::sync::mpsc::Receiver<StreamEvent>,
) -> Result<Vec<i32>, String> {
    let mut toks = Vec::new();
    loop {
        match rx.recv_timeout(Duration::from_secs(20)) {
            Ok(StreamEvent::Token(t)) => toks.push(t),
            Ok(StreamEvent::Done(resp)) => {
                assert_eq!(resp.tokens, toks, "streamed tokens disagree with done frame");
                return Ok(toks);
            }
            Ok(StreamEvent::Error { message, .. }) => return Err(message),
            Err(e) => panic!("routed stream hung: {e}"),
        }
    }
}

/// Wait (bounded) for a predicate driven by the probe loop.
fn eventually(what: &str, mut pred: impl FnMut() -> bool) {
    for _ in 0..100 {
        if pred() {
            return;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("timed out waiting for {what}");
}

fn stop_all(workers: Vec<(Server, ReplicaServer)>) {
    for (server, mut rs) in workers {
        rs.stop();
        server.stop();
    }
}

#[test]
fn routed_greedy_streams_match_in_process() {
    let (workers, fleet) = start_fleet(2);
    // Independent reference engine — same artifact, same seed. Greedy is
    // rng-free, so every replica must emit byte-identical streams.
    let (reference, mut ref_rs) = start_replica();
    let prompts: Vec<Vec<i32>> =
        vec![vec![1, 2, 3], vec![4, 5], vec![6, 7, 8, 9], vec![10, 11, 1], vec![2, 9]];
    let subs: Vec<_> = prompts
        .iter()
        .map(|p| fleet.try_submit_stream(greedy(p, 6), 32, None).unwrap())
        .collect();
    let mut used = std::collections::BTreeSet::new();
    for (p, sub) in prompts.iter().zip(subs) {
        used.insert(sub.replica.expect("router must stamp the serving replica"));
        let got = collect(&sub.rx).unwrap();
        let want = reference.handle.generate(greedy(p, 6)).unwrap();
        assert_eq!(got, want.tokens, "routed stream diverged for prompt {p:?}");
    }
    assert!(used.len() > 1, "5 concurrent streams never left replica 0: {used:?}");
    let rep = fleet.drain(Duration::from_secs(2)).unwrap();
    assert_eq!(rep.finished + rep.aborted + rep.dropped_queued, 0);
    let mem = fleet.mem_report().unwrap();
    assert_eq!(mem.decode_sessions_live, 0, "fleet leaked sessions");
    fleet.shutdown();
    ref_rs.stop();
    reference.stop();
    stop_all(workers);
}

#[test]
fn session_affinity_survives_interleaved_load() {
    let (workers, fleet) = start_fleet(2);
    // Establish pins while both streams are live: least-loaded dispatch
    // must split two concurrent sessions across the two idle replicas.
    let sa = fleet.try_submit_stream(greedy(&[1, 2, 3], 8), 32, Some("sess-a")).unwrap();
    let sb = fleet.try_submit_stream(greedy(&[4, 5, 6], 8), 32, Some("sess-b")).unwrap();
    let (pin_a, pin_b) = (sa.replica.unwrap(), sb.replica.unwrap());
    assert_ne!(pin_a, pin_b, "two live sessions on idle fleet must spread");
    collect(&sa.rx).unwrap();
    collect(&sb.rx).unwrap();
    // Interleaved rounds: unpinned background load plus both sessions,
    // submitted in orders that would flip them under pure least-loaded
    // dispatch. The pin must win every time.
    for round in 0..4 {
        let bg: Vec<_> = (0..2)
            .map(|i| {
                fleet
                    .try_submit_stream(greedy(&[7 + i, 2, round + 1], 4), 32, None)
                    .unwrap()
            })
            .collect();
        let sb = fleet.try_submit_stream(greedy(&[4, 5, 6], 4), 32, Some("sess-b")).unwrap();
        let sa = fleet.try_submit_stream(greedy(&[1, 2, 3], 4), 32, Some("sess-a")).unwrap();
        assert_eq!(sa.replica.unwrap(), pin_a, "round {round}: sess-a migrated");
        assert_eq!(sb.replica.unwrap(), pin_b, "round {round}: sess-b migrated");
        for sub in bg.iter().chain([&sa, &sb]) {
            collect(&sub.rx).unwrap();
        }
    }
    assert_eq!(fleet.pinned_sessions(), 2);
    fleet.drain(Duration::from_secs(2)).unwrap();
    assert_eq!(fleet.pinned_sessions(), 0, "drain must clear affinity pins");
    fleet.shutdown();
    stop_all(workers);
}

#[test]
fn replica_kill_fails_over_and_leaks_nothing() {
    let (mut workers, fleet) = start_fleet(2);
    let (reference, mut ref_rs) = start_replica();
    // A stream in flight on each replica, so the kill provably hits one.
    let s0 = fleet.try_submit_stream(greedy(&[1, 2, 3], 16), 32, None).unwrap();
    let s1 = fleet.try_submit_stream(greedy(&[4, 5, 6], 16), 32, None).unwrap();
    let victim = s0.replica.unwrap();
    assert_ne!(victim, s1.replica.unwrap());
    workers[victim].1.kill();
    // The victim's stream must end with a terminal event — a clean error
    // (connection severed mid-stream) or, if the race favoured it, done.
    let _ = collect(&s0.rx);
    collect(&s1.rx).unwrap();
    // Probes mark the dead replica down; new requests fail over to the
    // survivor (transport errors at dispatch retry the next candidate
    // immediately — no window where the fleet bounces work it could do).
    eventually("victim mark-down", || !fleet.replica_up(victim));
    for p in [vec![2, 3, 4], vec![9, 8]] {
        let sub = fleet.try_submit_stream(greedy(&p, 5), 32, None).unwrap();
        assert_ne!(sub.replica.unwrap(), victim, "dispatched to a dead replica");
        let got = collect(&sub.rx).unwrap();
        let want = reference.handle.generate(greedy(&p, 5)).unwrap();
        assert_eq!(got, want.tokens, "failover stream diverged for prompt {p:?}");
    }
    // The severed connection retired its session on the victim's engine:
    // nothing may leak even though the worker was cut off mid-stream.
    let victim_handle = workers[victim].0.handle.clone();
    eventually("victim session retirement", || {
        victim_handle.mem_report().is_some_and(|m| m.decode_sessions_live == 0)
    });
    fleet.drain(Duration::from_secs(2));
    fleet.shutdown();
    ref_rs.stop();
    reference.stop();
    stop_all(workers);
}

#[test]
fn param_broadcast_is_epoch_synchronized() {
    let (mut workers, fleet) = start_fleet(2);
    // Fresh host tensors from a probe load of the same artifact — same
    // weights, so post-broadcast outputs stay byte-identical.
    let probe = hyena::backend::load(
        BackendKind::Native,
        &PathBuf::from("artifacts/golden_tiny"),
        0,
    )
    .unwrap();
    let params = probe.params_host().unwrap();
    let epoch = fleet.broadcast_params(&params).unwrap();
    assert!(epoch >= 1);
    for (k, (server, _)) in workers.iter().enumerate() {
        let got = server.handle.mem_report().unwrap().params_epoch;
        assert_eq!(got, epoch, "replica {k} missed the broadcast");
    }
    assert_eq!(fleet.mem_report().unwrap().params_epoch, epoch);
    let (reference, mut ref_rs) = start_replica();
    let sub = fleet.try_submit_stream(greedy(&[3, 1, 4], 6), 32, None).unwrap();
    let want = reference.handle.generate(greedy(&[3, 1, 4], 6)).unwrap();
    assert_eq!(collect(&sub.rx).unwrap(), want.tokens);
    // Mixed-epoch guard: a replica that misses a broadcast (down while it
    // happened) must stay out of the candidate set when it reappears at
    // the old epoch, and rejoin once its engine catches up.
    workers[0].1.kill();
    eventually("replica 0 mark-down", || !fleet.replica_up(0));
    let epoch2 = fleet.broadcast_params(&params).unwrap();
    assert!(epoch2 > epoch);
    let handle0 = workers[0].0.handle.clone();
    let revived = ReplicaServer::start(handle0.clone(), "127.0.0.1:0").unwrap();
    fleet.set_replica_addr(0, revived.addr());
    workers[0].1 = revived;
    // Probes reach it again, but its epoch is stale — it must be held out.
    std::thread::sleep(Duration::from_millis(400));
    assert!(!fleet.replica_up(0), "stale-epoch replica rejoined the candidate set");
    for _ in 0..8 {
        let sub = fleet.try_submit_stream(greedy(&[5, 5], 3), 32, None).unwrap();
        assert_eq!(sub.replica.unwrap(), 1, "dispatch reached a stale-epoch replica");
        collect(&sub.rx).unwrap();
    }
    // Engine catches up (out-of-band reload) → probes mark it up again.
    handle0.set_params(params).unwrap();
    eventually("replica 0 rejoin at current epoch", || fleet.replica_up(0));
    fleet.drain(Duration::from_secs(2));
    fleet.shutdown();
    ref_rs.stop();
    reference.stop();
    stop_all(workers);
}

#[test]
fn fleet_drain_finishes_live_streams() {
    let (workers, fleet) = start_fleet(2);
    let subs: Vec<_> = (0..4)
        .map(|i| fleet.try_submit_stream(greedy(&[1 + i, 2, 3], 12), 32, None).unwrap())
        .collect();
    // Give the engines a beat so the streams are genuinely live, then
    // drain the whole fleet. Admission must close instantly; the live
    // streams must still reach their terminal events.
    std::thread::sleep(Duration::from_millis(20));
    let drainer = {
        let fleet = fleet.clone();
        std::thread::spawn(move || fleet.drain(Duration::from_secs(5)).unwrap())
    };
    eventually("draining flag", || fleet.is_draining());
    match fleet.try_submit_stream(greedy(&[1, 2], 2), 32, None) {
        Err(AdmitError::Draining) => {}
        other => panic!("draining fleet admitted a request: {:?}", other.is_ok()),
    }
    let mut finished = 0usize;
    for sub in &subs {
        if collect(&sub.rx).is_ok() {
            finished += 1;
        }
    }
    assert_eq!(finished, 4, "drain aborted streams inside a generous budget");
    // The report counts sessions still live at drain start; none may have
    // been force-aborted inside this generous budget.
    let rep = drainer.join().unwrap();
    assert_eq!(rep.aborted, 0, "drain report aborted streams: {rep:?}");
    assert_eq!(fleet.pinned_sessions(), 0);
    let mem = fleet.mem_report().unwrap();
    assert_eq!(mem.decode_sessions_live, 0, "drain leaked sessions");
    fleet.shutdown();
    stop_all(workers);
}
