//! Integration tests over real artifacts (require `make artifacts` first).
//!
//! Every test no-ops with a notice when the artifacts directory is absent so
//! `cargo test` stays green in a fresh checkout; CI runs `make test` which
//! builds artifacts first.

use std::path::PathBuf;
use std::time::Duration;

use hyena::coordinator::generation::{decode_batch, Sampling};
use hyena::coordinator::server::{GenerateRequest, Server};
use hyena::coordinator::trainer::{eval_accuracy, Trainer};
use hyena::metrics::flops::{flops_per_step, FlopShape};
use hyena::runtime::{Manifest, ModelState, Tensor};
use hyena::tasks::recall::RecallTask;
use hyena::util::json::Json;
use hyena::util::rng::Pcg;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("golden_tiny/manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing; integration test skipped");
        None
    }
}

#[test]
fn golden_forward_matches_python() {
    let Some(dir) = artifacts() else { return };
    let gdir = dir.join("golden_tiny");
    let model = ModelState::load(&gdir, 0).unwrap();
    let golden = Json::parse(&std::fs::read_to_string(gdir.join("golden.json")).unwrap()).unwrap();

    let tokens: Vec<i32> = golden
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i32)
        .collect();
    let shape: Vec<usize> = golden
        .get("logits_shape")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    let b = model.manifest.batch().unwrap();
    let l = model.manifest.seqlen().unwrap();
    let logits = model
        .forward(&[Tensor::from_i32(&[b, l], tokens).unwrap()])
        .unwrap();
    assert_eq!(logits.shape(), shape.as_slice());

    // Head-to-head numerics: python dumped the first 64 logits + global sum.
    let lf = logits.as_f32().unwrap();
    let head: Vec<f64> = golden
        .get("logits_head")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    for (i, (&got, &want)) in lf.iter().zip(head.iter()).enumerate() {
        assert!(
            (got as f64 - want).abs() < 1e-3 + 1e-3 * want.abs(),
            "logit {i}: rust {got} vs python {want}"
        );
    }
    let sum: f64 = lf.iter().map(|&x| x as f64).sum();
    let want_sum = golden.get("logits_sum").unwrap().as_f64().unwrap();
    assert!(
        (sum - want_sum).abs() < 1e-2 + 1e-4 * want_sum.abs(),
        "sum {sum} vs {want_sum}"
    );
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let Some(dir) = artifacts() else { return };
    let m1 = ModelState::load(&dir.join("golden_tiny"), 7).unwrap();
    let m2 = ModelState::load(&dir.join("golden_tiny"), 7).unwrap();
    let m3 = ModelState::load(&dir.join("golden_tiny"), 8).unwrap();
    let p1 = m1.params_host().unwrap();
    let p2 = m2.params_host().unwrap();
    let p3 = m3.params_host().unwrap();
    let flat =
        |ps: &[Tensor]| -> Vec<f32> { ps.iter().flat_map(|t| t.as_f32().map(|s| s.to_vec()).unwrap_or_default()).collect() };
    assert_eq!(flat(&p1), flat(&p2));
    assert_ne!(flat(&p1), flat(&p3));
}

#[test]
fn training_reduces_loss_on_fixed_batch() {
    let Some(dir) = artifacts() else { return };
    let mut model = ModelState::load(&dir.join("golden_tiny"), 0).unwrap();
    let task = RecallTask::new(16, 8, 2);
    let mut rng = Pcg::new(0);
    let fixed = task.sample_batch(&mut rng).to_tensors();
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..150 {
        last = model.train_step(&fixed).unwrap();
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.5,
        "loss did not drop: {first} -> {last}"
    );
}

#[test]
fn trainer_reports_curve_and_throughput() {
    let Some(dir) = artifacts() else { return };
    let mut model = ModelState::load(&dir.join("golden_tiny"), 1).unwrap();
    let task = RecallTask::new(16, 8, 2);
    let mut rng = Pcg::new(1);
    let mut tr = Trainer::new(&mut model, move || task.sample_batch(&mut rng).to_tensors());
    tr.quiet = true;
    tr.log_every = 5;
    let rep = tr.run(12).unwrap();
    assert_eq!(rep.steps, 12);
    assert!(rep.curve.len() >= 2);
    assert!(rep.steps_per_s > 0.0);
    assert!(rep.total_flops.unwrap() > 0.0);
    assert_eq!(rep.tokens_seen, 12 * 2 * 16);
}

#[test]
fn manifest_flops_match_host_mirror() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir.join("lm_hyena_s")).unwrap();
    let shape = FlopShape {
        depth: m.cfg_usize("depth").unwrap(),
        width: m.cfg_usize("width").unwrap(),
        seqlen: m.seqlen().unwrap(),
        vocab: m.vocab().unwrap(),
        mlp_ratio: m.config.get("mlp_ratio").unwrap().as_f64().unwrap(),
        order: m.cfg_usize("order").unwrap(),
        short_filter: m.cfg_usize("short_filter").unwrap(),
        is_attention: false,
    };
    let host = flops_per_step(&shape, m.batch().unwrap());
    let py = m.flops_per_step.unwrap();
    assert!(
        (host - py).abs() / py < 1e-9,
        "host {host} vs python {py}"
    );
}

#[test]
fn decode_is_pad_invariant() {
    let Some(dir) = artifacts() else { return };
    let model = ModelState::load(&dir.join("golden_tiny"), 0).unwrap();
    let mut rng = Pcg::new(0);
    let prompt = vec![3i32, 5, 7];
    // Decode alone vs alongside another request — greedy output of the first
    // row must be identical (batch padding cannot leak across rows).
    let solo = decode_batch(&model, &[prompt.clone()], &[4], Sampling::Greedy, &mut rng).unwrap();
    let duo = decode_batch(
        &model,
        &[prompt, vec![9i32, 1, 2, 6]],
        &[4, 4],
        Sampling::Greedy,
        &mut rng,
    )
    .unwrap();
    assert_eq!(solo[0], duo[0]);
}

#[test]
fn filters_artifact_materializes() {
    let Some(dir) = artifacts() else { return };
    let model = ModelState::load(&dir.join("golden_tiny"), 0).unwrap();
    let h = model.dump_filters().unwrap();
    assert_eq!(h.shape().len(), 3); // (N, D, L)
    assert_eq!(h.shape()[2], model.manifest.seqlen().unwrap());
    assert!(h.as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn eval_accuracy_runs_on_untrained_model() {
    let Some(dir) = artifacts() else { return };
    let model = ModelState::load(&dir.join("golden_tiny"), 0).unwrap();
    let task = RecallTask::new(16, 8, 2);
    let mut rng = Pcg::new(2);
    let mut src = move || task.sample_batch(&mut rng).to_tensors();
    let acc = eval_accuracy(&model, &mut src, 4).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn server_round_trip() {
    let Some(dir) = artifacts() else { return };
    let server = Server::start(dir.join("golden_tiny"), 0, Duration::from_millis(5)).unwrap();
    let handles: Vec<_> = (0..4)
        .map(|i| {
            server.handle.submit(GenerateRequest {
                prompt: vec![1 + i, 2, 3],
                max_new: 3,
                sampling: Sampling::Greedy,
                deadline: None,
                trace_id: 0,
            })
        })
        .collect();
    for h in handles {
        let resp = h.recv().unwrap().unwrap();
        assert_eq!(resp.tokens.len(), 3);
        assert!(resp.batch_occupancy >= 1);
    }
    server.stop();
}

#[test]
fn rejects_oversized_prompt() {
    let Some(dir) = artifacts() else { return };
    let model = ModelState::load(&dir.join("golden_tiny"), 0).unwrap();
    let l = model.manifest.seqlen().unwrap();
    let long = vec![0i32; l + 1];
    let mut rng = Pcg::new(0);
    assert!(decode_batch(&model, &[long], &[1], Sampling::Greedy, &mut rng).is_err());
}
