//! Loopback end-to-end tests for the telemetry subsystem: real sockets
//! against a real engine (`golden_tiny`, native backend), covering the
//! observability gates — `GET /metrics` serving well-formed Prometheus
//! text whose counter deltas agree with what the client saw on the wire,
//! `GET /trace` carrying per-stage spans for a real request, SSE `error`
//! events stamped with the request's trace id, and the fleet `metrics`
//! RPC merging replica snapshots into aggregate + `replica="K"` series.
//!
//! The metrics registry and trace ring are process-global and tests run
//! in parallel, so every assertion is delta-based (`>=` across scrapes)
//! or keyed by a test-owned trace id — never an absolute counter value.

use std::path::PathBuf;
use std::time::Duration;

use hyena::backend::BackendKind;
use hyena::coordinator::server::{Engine, Server};
use hyena::net::client::{generate_body, scrape_counter, Fault, HttpClient};
use hyena::net::router::{FleetConfig, FleetHandle, ReplicaServer};
use hyena::net::server::NetServer;
use hyena::net::NetConfig;
use hyena::obs;
use hyena::util::json::Json;

/// Engine + listener on a free loopback port, logs off.
fn start_stack() -> (Server, NetServer) {
    let server = Server::start_kind(
        BackendKind::Native,
        PathBuf::from("artifacts/golden_tiny"),
        0,
        Duration::from_millis(5),
        None,
        None,
        None,
    )
    .unwrap();
    let cfg = NetConfig {
        addr: "127.0.0.1:0".into(),
        conn_threads: 8,
        quiet: true,
        ..NetConfig::default()
    };
    let net = NetServer::start(server.handle.clone(), cfg).unwrap();
    (server, net)
}

/// `/generate` body with an explicit client-chosen trace id (48-bit hex,
/// so `id_hex` round-trips it verbatim into `/trace` and SSE payloads).
fn traced_body(prompt: &[i32], max_new: usize, timeout_ms: u64, trace_hex: &str) -> String {
    let base = generate_body(prompt, max_new, timeout_ms);
    let mut v = Json::parse(&base).unwrap();
    if let Json::Obj(m) = &mut v {
        m.insert("trace_id".to_string(), Json::str(trace_hex));
    }
    v.to_string()
}

#[test]
fn metrics_endpoint_serves_consistent_prometheus_text() {
    let (server, net) = start_stack();
    let addr = net.addr();
    let mut c = HttpClient::connect(addr, Duration::from_secs(10)).unwrap();

    let before = c.get("/metrics").unwrap();
    assert_eq!(before.status, 200);
    assert!(
        before.header("content-type").is_some_and(|t| t.starts_with("text/plain")),
        "exposition content type: {:?}",
        before.headers
    );
    let before_text = String::from_utf8(before.body).unwrap();
    let tok0 = scrape_counter(&before_text, "hyena_tokens_generated_total").unwrap();
    let done0 = scrape_counter(&before_text, "hyena_streams_completed_total").unwrap();

    let mut my_tokens = 0usize;
    for _ in 0..3 {
        let out = c.generate_stream(&generate_body(&[1, 2, 3], 5, 0), Fault::None).unwrap();
        assert_eq!(out.status, 200, "stream rejected: {:?}", out.reject);
        assert!(out.done.is_some());
        my_tokens += out.tokens.len();
    }
    assert!(my_tokens > 0);

    let after_text = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
    // Well-formed exposition: every non-comment line is `name[{labels}] value`.
    for line in after_text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let (name, value) = line.rsplit_once(' ').expect("sample line has a value");
        assert!(!name.is_empty());
        assert!(
            value.parse::<f64>().is_ok() || value == "+Inf",
            "unparseable sample value in {line:?}"
        );
    }
    assert!(after_text.contains("# TYPE hyena_http_requests_total counter"));
    assert!(after_text.contains("# TYPE hyena_ttfb_us histogram"));
    assert!(after_text.contains("hyena_ttfb_us_bucket{le=\"+Inf\"}"));
    assert!(after_text.contains("# TYPE hyena_inflight_requests gauge"));
    // Counter deltas: the registry is shared with parallel tests, so the
    // deltas are lower-bounded by this client's traffic, never exact.
    let tok1 = scrape_counter(&after_text, "hyena_tokens_generated_total").unwrap();
    let done1 = scrape_counter(&after_text, "hyena_streams_completed_total").unwrap();
    assert!(
        tok1 - tok0 >= my_tokens as u64,
        "tokens_generated advanced {} for {} tokens on the wire",
        tok1 - tok0,
        my_tokens
    );
    assert!(done1 - done0 >= 3, "streams_completed advanced {}", done1 - done0);

    let report = net.finish().unwrap();
    assert_eq!(report.leaked_sessions, 0);
    server.stop();
}

#[test]
fn trace_endpoint_reports_per_stage_spans() {
    let (server, net) = start_stack();
    let addr = net.addr();
    let trace_hex = "c0ffee0b5e2e"; // test-owned id, 48-bit hex
    let mut c = HttpClient::connect(addr, Duration::from_secs(10)).unwrap();
    let out = c
        .generate_stream(&traced_body(&[4, 5, 6], 6, 0, trace_hex), Fault::None)
        .unwrap();
    assert_eq!(out.status, 200);
    assert!(out.done.is_some());

    let resp = c.get("/trace?n=256").unwrap();
    assert_eq!(resp.status, 200);
    let dump = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let traces = dump.get("traces").unwrap().as_arr().unwrap();
    let t = traces
        .iter()
        .find(|t| t.get("trace_id").and_then(|v| v.as_str()) == Some(trace_hex))
        .expect("our trace in the ring");
    assert_eq!(t.get("status").unwrap().as_str(), Some("done"));
    let names: Vec<String> = t
        .get("spans")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.get("name").unwrap().as_str().unwrap().to_string())
        .collect();
    // The in-process engine shares the hub, so one trace carries both the
    // front end's stages and the coordinator's.
    for want in ["parse", "admission", "queue_wait", "prefill", "stream"] {
        assert!(names.contains(&want.to_string()), "span {want:?} missing from {names:?}");
    }
    assert!(
        names.iter().filter(|n| *n == "decode_round").count() >= 1,
        "no decode rounds traced: {names:?}"
    );

    let report = net.finish().unwrap();
    assert_eq!(report.leaked_sessions, 0);
    server.stop();
}

#[test]
fn error_events_carry_the_trace_id() {
    let (server, net) = start_stack();
    let addr = net.addr();
    // Hold the engine busy so a 1 ms budget expires in the queue and the
    // stream terminates with an explicit error event.
    let flood: Vec<_> = (0..2000)
        .map(|i| {
            server.handle.submit(hyena::coordinator::server::GenerateRequest {
                prompt: vec![1 + (i % 11) as i32, 2, 3],
                max_new: 8,
                sampling: hyena::coordinator::generation::Sampling::Greedy,
                deadline: None,
                trace_id: 0,
            })
        })
        .collect();
    let trace_hex = "deadbeef0042";
    let mut c = HttpClient::connect(addr, Duration::from_secs(30)).unwrap();
    let out = c
        .generate_stream(&traced_body(&[1, 2, 3], 8, 1, trace_hex), Fault::None)
        .unwrap();
    assert_eq!(out.status, 200);
    let err = out.error.expect("expired stream ends with an error event");
    assert_eq!(
        err.get("trace_id").and_then(|v| v.as_str()),
        Some(trace_hex),
        "error event payload: {err:?}"
    );
    for rx in flood {
        rx.recv().unwrap().unwrap();
    }
    let report = net.finish().unwrap();
    assert_eq!(report.leaked_sessions, 0);
    server.stop();
}

#[test]
fn fleet_metrics_rpc_merges_replica_series() {
    // Replicas here are threads around local engines (the RPC wire is
    // real; see router_e2e.rs) — all sharing this process's registry, so
    // the assertions are structural: the merge must carry an unlabeled
    // aggregate plus one `replica="K"` copy per worker, and the aggregate
    // must dominate any single replica's value.
    let workers: Vec<(Server, ReplicaServer)> = (0..2)
        .map(|_| {
            let server = Server::start_kind(
                BackendKind::Native,
                PathBuf::from("artifacts/golden_tiny"),
                0,
                Duration::from_millis(5),
                None,
                None,
                None,
            )
            .unwrap();
            let rs = ReplicaServer::start(server.handle.clone(), "127.0.0.1:0").unwrap();
            (server, rs)
        })
        .collect();
    let addrs: Vec<_> = workers.iter().map(|(_, rs)| rs.addr()).collect();
    let fleet = FleetHandle::connect(
        &addrs,
        FleetConfig { probe_ms: 40, quiet: true, ..FleetConfig::default() },
    )
    .unwrap();

    let snap = fleet.metrics();
    let name = "hyena_http_requests_total";
    let agg = snap
        .series
        .iter()
        .find(|s| s.name == name && s.labels.is_empty())
        .expect("aggregate series");
    for k in 0..2 {
        let labeled = snap
            .series
            .iter()
            .find(|s| {
                s.name == name
                    && s.labels == vec![("replica".to_string(), k.to_string())]
            })
            .unwrap_or_else(|| panic!("replica {k} series missing"));
        match (&agg.value, &labeled.value) {
            (obs::Value::Counter(a), obs::Value::Counter(r)) => {
                assert!(a >= r, "aggregate {a} < replica {k} value {r}");
            }
            other => panic!("unexpected kinds: {other:?}"),
        }
    }
    // The merged snapshot renders: replica labels survive into the text.
    let text = obs::render_prometheus(&snap);
    assert!(text.contains("hyena_http_requests_total{replica=\"0\"}"));
    assert!(text.contains("hyena_http_requests_total{replica=\"1\"}"));

    fleet.shutdown();
    for (server, mut rs) in workers {
        rs.stop();
        server.stop();
    }
}
