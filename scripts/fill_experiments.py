#!/usr/bin/env python3
"""Fill EXPERIMENTS.md placeholders from results/*.csv (run after the
experiment chain / benches). Idempotent: placeholders are kept as HTML
comments next to the inserted tables so re-running refreshes them."""
import csv
import io
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TAGS = {
    "FIGC1": "figC_1.csv",
    "TABLE47": "table4_7.csv",
    "FIGD": "figD_filters.csv",
    "FIG41": "fig4_1.csv",
    "TABLE43": "table4_3.csv",
    "FIG42": "fig4_2.csv",
    "TABLE42": "table4_2.csv",
    "TABLE45": "table4_5.csv",
    "ABLATIONS": "ablations.csv",
    "TABLEC1": "tableC_1.csv",
    "LMPRETRAIN": "lm_pretrain_lm_hyena_s.csv",
    "FIG43": "fig4_3.csv",
    "PERF_L3": "coordinator_micro.csv",
    # A tag may hold several CSVs (filled in order; missing ones skipped).
    "PERF_NATIVE": ["native_fftconv.csv", "native_step.csv", "native_serve.csv"],
    "PERF_LONGCTX": "native_fftconv_longctx.csv",
    "PERF_SERVE_NET": "native_serve_net.csv",
    "PERF_ROUTER": "native_router.csv",
    "PERF_OBS": "native_obs.csv",
    "PERF_L2": "perf_donation.csv",
}


def csv_to_md(path: str) -> str:
    with open(path) as f:
        rows = list(csv.reader(f))
    if not rows:
        return "*(empty)*"
    out = io.StringIO()
    out.write("| " + " | ".join(rows[0]) + " |\n")
    out.write("|" + "---|" * len(rows[0]) + "\n")
    for r in rows[1:]:
        out.write("| " + " | ".join(r) + " |\n")
    return out.getvalue()


def main() -> None:
    md_path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(md_path).read()
    for tag, fnames in TAGS.items():
        if isinstance(fnames, str):
            fnames = [fnames]
        marker = f"<!-- {tag} -->"
        if marker not in text:
            continue
        tables, filled = [], []
        for fname in fnames:
            path = os.path.join(ROOT, "results", fname)
            if not os.path.exists(path):
                print(f"  {tag}: {fname} missing, skipped")
                continue
            tables.append(csv_to_md(path))
            filled.append(fname)
        if not tables:
            continue
        # Replace marker + any previously inserted tables (runs of |-lines,
        # each optionally followed by one blank separator) with fresh ones.
        pattern = re.compile(re.escape(marker) + r"\n(?:(?:\|[^\n]*\n)+\n?)*")
        text = pattern.sub(marker + "\n" + "\n".join(tables), text)
        print(f"  {tag}: filled from {', '.join(filled)}")
    open(md_path, "w").write(text)


if __name__ == "__main__":
    main()
