#!/usr/bin/env bash
# CI/dev gate: formatting, lints, build, tests — keeps docs and code in sync.
#
# Usage: scripts/check.sh [--fix|lint-smoke|bench-smoke|serve-smoke|decode-smoke|kernel-smoke|longctx-smoke|serve-net-smoke|router-smoke|obs-smoke]
#   --fix        run `cargo fmt` (writing) instead of `cargo fmt --check`
#   lint-smoke   static-analysis gate (DESIGN.md §Static-Analysis): runs the
#                dependency-free rustcheck analyzer over rust/src, rust/tests,
#                benches/ and examples/ in --strict mode. Needs only python3 —
#                no cargo — so it is the one gate that runs in every
#                container. Nonzero exit on any unallowlisted finding
#                (balance/mod-wiring/arity/trait-impl/duplicates, plus the
#                partial_cmp-unwrap, unsafe-without-SAFETY, kernel-parity,
#                struct-lit-field and nondeterminism lints).
#   bench-smoke  perf regression gate: run the FFTConv bench at L ∈ {1K, 8K}
#                with 2 threads; fails on panic or if the real-FFT conv is
#                not faster than the direct O(L²) conv at 8K.
#   serve-smoke  serving gate: (1) the native_serve bench must show a ≤ L/8
#                prompt served through its plan bucket beating the full-pad
#                inference path, and (2) the real server must survive mixed-
#                length traffic with every request routed to its smallest
#                covering bucket (no full-pad fallback, no panics).
#   decode-smoke streaming-decode gate: (1) the native_decode bench must
#                show streamed per-token decode ≥ 2× faster than the
#                full-recompute path at L = 4096 with token-identical
#                greedy output, and (2) the real server must stream mixed-
#                length traffic through resident sessions (every generated
#                token beyond a request's first served by decode_step, no
#                prefix recompute, no leaked sessions, no panics).
#   kernel-smoke vectorized-kernel gate (DESIGN.md §Kernels): runs the
#                native_step kernel micro-axes and the native_decode
#                batched-stepping axis under HYENA_KERNEL=scalar and
#                HYENA_KERNEL=simd. Fails if the dispatcher does not honour
#                the forcing env, if SIMD does not win ≥ 1.5× on the
#                dense-axpy / decode-dot micro-axes (on SIMD-capable CPUs),
#                if batched decode_step_batch does not beat serial stepping
#                at occupancy 4, or if the greedy token streams differ
#                between the scalar and SIMD kernel paths.
#   serve-net-smoke network-serving gate (DESIGN.md §Serving-Net): (1) the
#                loopback e2e tests — greedy byte-identity over HTTP/SSE,
#                deterministic 429 + Retry-After under overload, chaos
#                disconnects and drains leaking zero sessions; (2) the
#                native_serve_net bench in --smoke mode (ledger key
#                `serve_net`); (3) a live `serve --listen` process driven
#                by the chaos loadgen: an overload burst must provoke 429s
#                (each carrying Retry-After — loadgen fails otherwise), a
#                chaos pass must not wedge the listener, and SIGTERM must
#                drain to exit 0 with `0 leaked sessions` in the report.
#   router-smoke replica-parallel serving gate (DESIGN.md §Router): (1) the
#                router e2e tests — greedy byte-identity through the fleet,
#                session affinity, replica-kill failover, epoch-synchronized
#                parameter broadcast, fleet drain; (2) the native_router
#                bench in --smoke mode (ledger key `router`): N=2 worker
#                processes must deliver >= 1.7x the aggregate tok/s of N=1
#                with token-identical greedy streams; (3) a live
#                `serve --listen --replicas 2` fleet: an overload burst must
#                provoke 429s (each with Retry-After), a killed worker
#                process must be respawned and traffic keep flowing, and
#                SIGTERM must drain fleet-wide to exit 0 with `0 leaked
#                sessions` in the report.
#   obs-smoke    observability gate (DESIGN.md §Observability): (1) the
#                obs e2e tests — /metrics exposition consistency, /trace
#                per-stage spans, trace-stamped error events, fleet
#                metrics-RPC merge; (2) the native_obs bench in --smoke
#                mode (ledger key `obs`): HYENA_PROF=1 decode overhead
#                must stay ≤ 3%; (3) a live `serve --listen --replicas 2`
#                fleet scraped before/after a loadgen --scrape run: the
#                aggregate /metrics counter deltas must agree with what
#                the client saw on the wire, /metrics must carry
#                replica-labeled series, /trace must return spans for the
#                traffic just served, and SIGTERM must drain to exit 0
#                with `0 leaked sessions`.
#   longctx-smoke long-context gate (DESIGN.md §Long-context): (1) every
#                longctx_* unit test — chunked prefill bitwise at the full
#                bucket, ≤ tolerance vs the extended monolithic oracle,
#                O(chunk) prefill activation bytes, sliding-window decode —
#                and (2) the native_fftconv --longctx axis: a 64K signal
#                streamed through 8K overlap-save chunks must stay ≤ 1e-4
#                relative against the monolithic plan (result persists to
#                BENCH_native.json under key `longctx`).
set -euo pipefail
cd "$(dirname "$0")/.."

run_lint() {
    echo "==> lint-smoke: rustcheck static-analysis gate (python3, no cargo)"
    python3 scripts/rustcheck --strict
    echo "check.sh: lint-smoke green"
}

if [ "${1:-}" = "lint-smoke" ]; then
    run_lint
    exit 0
fi

# Every other target drives cargo. Without a toolchain, still run the static
# gate (python3-only), then skip the cargo stages with an actionable message
# instead of a bare failure.
if ! command -v cargo >/dev/null 2>&1; then
    run_lint
    echo "skip: cargo not found on PATH — skipping the '${1:-full}' cargo stages" >&2
    echo "      (fmt/clippy/build/test/bench). The rustcheck static gate above" >&2
    echo "      DID run and passed. For the full gate, install a Rust toolchain" >&2
    echo "      (https://rustup.rs) and re-run: scripts/check.sh ${1:-}" >&2
    exit 0
fi

if [ "${1:-}" = "bench-smoke" ]; then
    echo "==> bench-smoke: native_fftconv (--smoke, 2 threads, L <= 8K)"
    cargo bench --bench native_fftconv -- --smoke --threads 2
    echo "check.sh: bench-smoke green"
    exit 0
fi

if [ "${1:-}" = "serve-smoke" ]; then
    echo "==> serve-smoke: native_serve bench gate (--smoke, 2 threads)"
    cargo bench --bench native_serve -- --smoke --threads 2
    echo "==> serve-smoke: live server, mixed-length traffic, bucket routing enforced"
    cargo run --release --bin hyena -- serve --model lm_hyena_s --backend native \
        --requests 12 --mixed --require-buckets --greedy --threads 2 --seed 0
    echo "check.sh: serve-smoke green"
    exit 0
fi

if [ "${1:-}" = "decode-smoke" ]; then
    echo "==> decode-smoke: native_decode bench gate (--smoke, 2 threads)"
    cargo bench --bench native_decode -- --smoke --threads 2
    echo "==> decode-smoke: live server, mixed-length streamed sessions enforced"
    cargo run --release --bin hyena -- serve --model lm_hyena_s --backend native \
        --requests 12 --mixed --stream-decode --require-buckets --greedy \
        --threads 2 --seed 0
    echo "check.sh: decode-smoke green"
    exit 0
fi

if [ "${1:-}" = "serve-net-smoke" ]; then
    echo "==> serve-net-smoke: loopback e2e tests (HTTP/SSE, chaos, drain)"
    cargo test --release -q --test serve_net_e2e
    echo "==> serve-net-smoke: native_serve_net bench gate (--smoke, 2 threads)"
    cargo bench --bench native_serve_net -- --smoke --threads 2
    echo "==> serve-net-smoke: live listener + loadgen (overload burst, chaos, SIGTERM drain)"
    cargo build --release --bin hyena
    log=$(mktemp)
    ./target/release/hyena serve --model lm_hyena_s --backend native \
        --listen 127.0.0.1:0 --queue-cap 1 --threads 2 --quiet >"$log" 2>&1 &
    srv=$!
    addr=""
    for _ in $(seq 1 100); do
        addr=$(sed -n 's/^listening on \([0-9.]*:[0-9]*\).*/\1/p' "$log" | head -1)
        [ -n "$addr" ] && break
        sleep 0.1
    done
    if [ -z "$addr" ]; then
        echo "serve-net-smoke: listener never came up" >&2
        cat "$log" >&2
        kill "$srv" 2>/dev/null || true
        exit 1
    fi
    # Overload burst: 24 simultaneous streams against capacity 8 + queue 1
    # must bounce the surplus with 429; loadgen itself fails the run if any
    # 429 arrives without Retry-After, and retries until every stream lands.
    burst_out=$(./target/release/hyena loadgen --addr "$addr" --clients 24 --requests 1 \
        --burst --prompt-len 32 --max-new 64 --vocab 96 --seed 0)
    echo "$burst_out"
    if ! echo "$burst_out" | grep -qE '[1-9][0-9]* x 429'; then
        echo "serve-net-smoke: overload burst provoked no 429 backpressure" >&2
        kill "$srv" 2>/dev/null || true
        exit 1
    fi
    # Chaos pass on the live wire: injected disconnects and garbage must not
    # wedge the listener (the SIGTERM drain below proves nothing leaked).
    HYENA_CHAOS="disconnect:0.3,garbage:0.2,seed:7" ./target/release/hyena loadgen \
        --addr "$addr" --clients 6 --requests 4 --prompt-len 16 --max-new 32 \
        --vocab 96 --seed 1
    kill -TERM "$srv"
    rc=0
    wait "$srv" || rc=$?
    cat "$log"
    if [ "$rc" -ne 0 ]; then
        echo "serve-net-smoke: server exited rc=$rc after drain (leak gate)" >&2
        exit 1
    fi
    if ! grep -q ', 0 leaked sessions' "$log"; then
        echo "serve-net-smoke: drain report missing the zero-leak line" >&2
        exit 1
    fi
    rm -f "$log"
    echo "check.sh: serve-net-smoke green"
    exit 0
fi

if [ "${1:-}" = "router-smoke" ]; then
    echo "==> router-smoke: fleet e2e tests (identity, affinity, failover, epoch, drain)"
    cargo test --release -q --test router_e2e
    echo "==> router-smoke: native_router bench gate (--smoke: >= 1.7x at N=2, identity)"
    cargo bench --bench native_router -- --smoke
    echo "==> router-smoke: live 2-replica fleet (burst, worker kill + respawn, SIGTERM drain)"
    cargo build --release --bin hyena
    log=$(mktemp)
    ./target/release/hyena serve --model lm_hyena_s --backend native \
        --listen 127.0.0.1:0 --replicas 2 --queue-cap 1 --threads 2 --quiet >"$log" 2>&1 &
    srv=$!
    addr=""
    for _ in $(seq 1 200); do
        addr=$(sed -n 's/^listening on \([0-9.]*:[0-9]*\).*/\1/p' "$log" | head -1)
        [ -n "$addr" ] && break
        sleep 0.1
    done
    if [ -z "$addr" ]; then
        echo "router-smoke: fleet listener never came up" >&2
        cat "$log" >&2
        kill "$srv" 2>/dev/null || true
        exit 1
    fi
    # Overload burst across the fleet: the surplus must bounce with 429 and
    # every 429 must carry Retry-After (loadgen fails the run otherwise).
    burst_out=$(./target/release/hyena loadgen --addr "$addr" --clients 24 --requests 1 \
        --burst --prompt-len 32 --max-new 64 --vocab 96 --seed 0)
    echo "$burst_out"
    if ! echo "$burst_out" | grep -qE '[1-9][0-9]* x 429'; then
        echo "router-smoke: overload burst provoked no 429 backpressure" >&2
        kill "$srv" 2>/dev/null || true
        exit 1
    fi
    # Kill one worker process outright: the router must mark it down, the
    # supervisor must respawn it, and traffic must keep flowing meanwhile.
    kid=$(pgrep -P "$srv" -f 'replica --model' | head -1)
    if [ -z "$kid" ]; then
        echo "router-smoke: no replica worker process found to kill" >&2
        kill "$srv" 2>/dev/null || true
        exit 1
    fi
    kill -KILL "$kid"
    sleep 2
    recover_out=$(./target/release/hyena loadgen --addr "$addr" --clients 4 --requests 2 \
        --prompt-len 16 --max-new 32 --vocab 96 --seed 1)
    echo "$recover_out"
    if ! echo "$recover_out" | grep -q '8 requests: 8 ok'; then
        echo "router-smoke: traffic did not fully recover after worker kill" >&2
        kill "$srv" 2>/dev/null || true
        exit 1
    fi
    if ! grep -q 'respawning' "$log"; then
        echo "router-smoke: supervisor never respawned the killed worker" >&2
        kill "$srv" 2>/dev/null || true
        exit 1
    fi
    kill -TERM "$srv"
    rc=0
    wait "$srv" || rc=$?
    cat "$log"
    if [ "$rc" -ne 0 ]; then
        echo "router-smoke: fleet exited rc=$rc after drain (leak gate)" >&2
        exit 1
    fi
    if ! grep -q ', 0 leaked sessions' "$log"; then
        echo "router-smoke: drain report missing the zero-leak line" >&2
        exit 1
    fi
    rm -f "$log"
    echo "check.sh: router-smoke green"
    exit 0
fi

if [ "${1:-}" = "obs-smoke" ]; then
    echo "==> obs-smoke: obs e2e tests (/metrics, /trace, trace-stamped errors, fleet merge)"
    cargo test --release -q --test obs_e2e
    echo "==> obs-smoke: native_obs bench gate (--smoke: HYENA_PROF overhead <= 3%)"
    cargo bench --bench native_obs -- --smoke --threads 2
    echo "==> obs-smoke: live 2-replica fleet, scrape-bracketed loadgen, /trace spans, SIGTERM drain"
    cargo build --release --bin hyena
    log=$(mktemp)
    ./target/release/hyena serve --model lm_hyena_s --backend native \
        --listen 127.0.0.1:0 --replicas 2 --threads 2 --quiet >"$log" 2>&1 &
    srv=$!
    addr=""
    for _ in $(seq 1 200); do
        addr=$(sed -n 's/^listening on \([0-9.]*:[0-9]*\).*/\1/p' "$log" | head -1)
        [ -n "$addr" ] && break
        sleep 0.1
    done
    if [ -z "$addr" ]; then
        echo "obs-smoke: fleet listener never came up" >&2
        cat "$log" >&2
        kill "$srv" 2>/dev/null || true
        exit 1
    fi
    # --scrape brackets the run with GET /metrics and makes loadgen itself
    # fail if the server's tokens_generated / admission_rejected deltas
    # disagree with the streams the client actually saw.
    ./target/release/hyena loadgen --addr "$addr" --clients 4 --requests 3 \
        --prompt-len 16 --max-new 32 --vocab 96 --seed 0 --scrape
    http_get() {
        python3 -c "import urllib.request,sys; \
sys.stdout.write(urllib.request.urlopen('http://$addr'+sys.argv[1], timeout=10).read().decode())" "$1"
    }
    # Fleet exposition: the aggregate line plus per-replica labeled series.
    metrics=$(http_get /metrics)
    for want in 'hyena_tokens_generated_total ' 'replica="0"' 'replica="1"' \
        '# TYPE hyena_ttfb_us histogram'; do
        if ! echo "$metrics" | grep -qF "$want"; then
            echo "obs-smoke: /metrics is missing $want" >&2
            echo "$metrics" | head -40 >&2
            kill "$srv" 2>/dev/null || true
            exit 1
        fi
    done
    # The traffic just served must be traceable: finished traces with the
    # front end's stream span and a done status.
    trace=$(http_get '/trace?n=64')
    for want in '"status":"done"' '"name":"stream"' '"name":"admission"'; do
        if ! echo "$trace" | grep -qF "$want"; then
            echo "obs-smoke: /trace is missing $want" >&2
            echo "$trace" | head -5 >&2
            kill "$srv" 2>/dev/null || true
            exit 1
        fi
    done
    kill -TERM "$srv"
    rc=0
    wait "$srv" || rc=$?
    cat "$log"
    if [ "$rc" -ne 0 ]; then
        echo "obs-smoke: fleet exited rc=$rc after drain (leak gate)" >&2
        exit 1
    fi
    if ! grep -q ', 0 leaked sessions' "$log"; then
        echo "obs-smoke: drain report missing the zero-leak line" >&2
        exit 1
    fi
    rm -f "$log"
    echo "check.sh: obs-smoke green"
    exit 0
fi

if [ "${1:-}" = "longctx-smoke" ]; then
    echo "==> longctx-smoke: chunked-prefill exactness + sliding-window unit tests"
    cargo test --release -q longctx
    echo "==> longctx-smoke: 64K overlap-save stream vs monolithic plan (<= 1e-4 rel)"
    cargo bench --bench native_fftconv -- --longctx --max-l 65536 --chunk 8192 --iters 2
    echo "check.sh: longctx-smoke green"
    exit 0
fi

if [ "${1:-}" = "kernel-smoke" ]; then
    echo "==> kernel-smoke: kernel micro-axes, scalar dispatch forced"
    HYENA_KERNEL=scalar cargo bench --bench native_step -- --smoke --threads 2
    echo "==> kernel-smoke: kernel micro-axes + SIMD gate (1.5x dense/dot where supported)"
    HYENA_KERNEL=simd cargo bench --bench native_step -- --smoke --threads 2
    echo "==> kernel-smoke: batched decode stepping (occupancy 4) + greedy fingerprints"
    log_scalar=$(mktemp); log_simd=$(mktemp)
    HYENA_KERNEL=scalar cargo bench --bench native_decode -- --smoke --threads 2 | tee "$log_scalar"
    HYENA_KERNEL=simd cargo bench --bench native_decode -- --smoke --threads 2 | tee "$log_simd"
    fp_scalar=$(grep -o 'greedy fingerprint: [0-9a-f]*' "$log_scalar" | tail -1)
    fp_simd=$(grep -o 'greedy fingerprint: [0-9a-f]*' "$log_simd" | tail -1)
    rm -f "$log_scalar" "$log_simd"
    if [ -z "$fp_scalar" ] || [ "$fp_scalar" != "$fp_simd" ]; then
        echo "kernel-smoke: greedy streams diverged between scalar and simd kernels" >&2
        echo "  scalar: ${fp_scalar:-<missing>}   simd: ${fp_simd:-<missing>}" >&2
        exit 1
    fi
    echo "kernel-smoke: scalar/simd greedy fingerprints match (${fp_scalar#*: })"
    echo "check.sh: kernel-smoke green"
    exit 0
fi

FIX=0
[ "${1:-}" = "--fix" ] && FIX=1

# The full gate always leads with the cargo-independent static pass.
run_lint

echo "==> cargo fmt"
if [ "$FIX" = 1 ]; then
    cargo fmt
else
    cargo fmt --check
fi

echo "==> cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "check.sh: all green"
