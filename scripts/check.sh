#!/usr/bin/env bash
# CI/dev gate: formatting, lints, build, tests — keeps docs and code in sync.
#
# Usage: scripts/check.sh [--fix|bench-smoke|serve-smoke|decode-smoke|kernel-smoke|longctx-smoke]
#   --fix        run `cargo fmt` (writing) instead of `cargo fmt --check`
#   bench-smoke  perf regression gate: run the FFTConv bench at L ∈ {1K, 8K}
#                with 2 threads; fails on panic or if the real-FFT conv is
#                not faster than the direct O(L²) conv at 8K.
#   serve-smoke  serving gate: (1) the native_serve bench must show a ≤ L/8
#                prompt served through its plan bucket beating the full-pad
#                inference path, and (2) the real server must survive mixed-
#                length traffic with every request routed to its smallest
#                covering bucket (no full-pad fallback, no panics).
#   decode-smoke streaming-decode gate: (1) the native_decode bench must
#                show streamed per-token decode ≥ 2× faster than the
#                full-recompute path at L = 4096 with token-identical
#                greedy output, and (2) the real server must stream mixed-
#                length traffic through resident sessions (every generated
#                token beyond a request's first served by decode_step, no
#                prefix recompute, no leaked sessions, no panics).
#   kernel-smoke vectorized-kernel gate (DESIGN.md §Kernels): runs the
#                native_step kernel micro-axes and the native_decode
#                batched-stepping axis under HYENA_KERNEL=scalar and
#                HYENA_KERNEL=simd. Fails if the dispatcher does not honour
#                the forcing env, if SIMD does not win ≥ 1.5× on the
#                dense-axpy / decode-dot micro-axes (on SIMD-capable CPUs),
#                if batched decode_step_batch does not beat serial stepping
#                at occupancy 4, or if the greedy token streams differ
#                between the scalar and SIMD kernel paths.
#   longctx-smoke long-context gate (DESIGN.md §Long-context): (1) every
#                longctx_* unit test — chunked prefill bitwise at the full
#                bucket, ≤ tolerance vs the extended monolithic oracle,
#                O(chunk) prefill activation bytes, sliding-window decode —
#                and (2) the native_fftconv --longctx axis: a 64K signal
#                streamed through 8K overlap-save chunks must stay ≤ 1e-4
#                relative against the monolithic plan (result persists to
#                BENCH_native.json under key `longctx`).
set -euo pipefail
cd "$(dirname "$0")/.."

# Fail fast, before any sub-target: every mode below needs cargo.
if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — scripts/check.sh (and all its" >&2
    echo "smoke targets) drive cargo fmt/clippy/build/test/bench." >&2
    echo "Install a Rust toolchain (https://rustup.rs) and re-run." >&2
    exit 1
fi

if [ "${1:-}" = "bench-smoke" ]; then
    echo "==> bench-smoke: native_fftconv (--smoke, 2 threads, L <= 8K)"
    cargo bench --bench native_fftconv -- --smoke --threads 2
    echo "check.sh: bench-smoke green"
    exit 0
fi

if [ "${1:-}" = "serve-smoke" ]; then
    echo "==> serve-smoke: native_serve bench gate (--smoke, 2 threads)"
    cargo bench --bench native_serve -- --smoke --threads 2
    echo "==> serve-smoke: live server, mixed-length traffic, bucket routing enforced"
    cargo run --release --bin hyena -- serve --model lm_hyena_s --backend native \
        --requests 12 --mixed --require-buckets --greedy --threads 2 --seed 0
    echo "check.sh: serve-smoke green"
    exit 0
fi

if [ "${1:-}" = "decode-smoke" ]; then
    echo "==> decode-smoke: native_decode bench gate (--smoke, 2 threads)"
    cargo bench --bench native_decode -- --smoke --threads 2
    echo "==> decode-smoke: live server, mixed-length streamed sessions enforced"
    cargo run --release --bin hyena -- serve --model lm_hyena_s --backend native \
        --requests 12 --mixed --stream-decode --require-buckets --greedy \
        --threads 2 --seed 0
    echo "check.sh: decode-smoke green"
    exit 0
fi

if [ "${1:-}" = "longctx-smoke" ]; then
    echo "==> longctx-smoke: chunked-prefill exactness + sliding-window unit tests"
    cargo test --release -q longctx
    echo "==> longctx-smoke: 64K overlap-save stream vs monolithic plan (<= 1e-4 rel)"
    cargo bench --bench native_fftconv -- --longctx --max-l 65536 --chunk 8192 --iters 2
    echo "check.sh: longctx-smoke green"
    exit 0
fi

if [ "${1:-}" = "kernel-smoke" ]; then
    echo "==> kernel-smoke: kernel micro-axes, scalar dispatch forced"
    HYENA_KERNEL=scalar cargo bench --bench native_step -- --smoke --threads 2
    echo "==> kernel-smoke: kernel micro-axes + SIMD gate (1.5x dense/dot where supported)"
    HYENA_KERNEL=simd cargo bench --bench native_step -- --smoke --threads 2
    echo "==> kernel-smoke: batched decode stepping (occupancy 4) + greedy fingerprints"
    log_scalar=$(mktemp); log_simd=$(mktemp)
    HYENA_KERNEL=scalar cargo bench --bench native_decode -- --smoke --threads 2 | tee "$log_scalar"
    HYENA_KERNEL=simd cargo bench --bench native_decode -- --smoke --threads 2 | tee "$log_simd"
    fp_scalar=$(grep -o 'greedy fingerprint: [0-9a-f]*' "$log_scalar" | tail -1)
    fp_simd=$(grep -o 'greedy fingerprint: [0-9a-f]*' "$log_simd" | tail -1)
    rm -f "$log_scalar" "$log_simd"
    if [ -z "$fp_scalar" ] || [ "$fp_scalar" != "$fp_simd" ]; then
        echo "kernel-smoke: greedy streams diverged between scalar and simd kernels" >&2
        echo "  scalar: ${fp_scalar:-<missing>}   simd: ${fp_simd:-<missing>}" >&2
        exit 1
    fi
    echo "kernel-smoke: scalar/simd greedy fingerprints match (${fp_scalar#*: })"
    echo "check.sh: kernel-smoke green"
    exit 0
fi

FIX=0
[ "${1:-}" = "--fix" ] && FIX=1

echo "==> cargo fmt"
if [ "$FIX" = 1 ]; then
    cargo fmt
else
    cargo fmt --check
fi

echo "==> cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "check.sh: all green"
