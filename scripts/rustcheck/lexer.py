"""A small but real Rust lexer.

Tokenizes enough of the language to make the downstream passes exact where
grep-based auditing is not: comments (line + nested block), string literals
(plain, raw ``r#"…"#``, byte ``b"…"``), char literals vs lifetimes, numeric
literals, identifiers (including raw ``r#ident``) and punctuation.  The
compound puncts ``::``, ``->``, ``=>``, ``..`` are fused so signature
scanning never miscounts ``>`` inside ``-> T``; shift operators are NOT
fused so ``Vec<Vec<T>>`` closes two generic depths.

Outputs, per file:

* ``tokens``   — ``Token(kind, text, line)`` stream with comments dropped,
* ``comments`` — ``(line, text)`` pairs (doc comments included) for the
  SAFETY lint,
* ``masked``   — the source text with comment bodies and literal contents
  replaced by spaces (newlines kept), so regex lints can never match inside
  a string or comment,
* ``errors``   — unclosed block comment / string / char diagnostics.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass
class Token:
    kind: str  # id | num | str | char | life | punct
    text: str
    line: int


@dataclass
class LexResult:
    tokens: List[Token] = field(default_factory=list)
    comments: List[Tuple[int, str]] = field(default_factory=list)
    masked: str = ""
    errors: List[Tuple[int, str]] = field(default_factory=list)


_ID_START = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ_")
_ID_CONT = _ID_START | set("0123456789")
_COMPOUND = ("::", "->", "=>", "..")


def lex(text: str, path: str = "<mem>") -> LexResult:
    res = LexResult()
    out = list(text)  # masked copy, mutated in place

    def blank(a: int, b: int) -> None:
        for j in range(a, b):
            if out[j] != "\n":
                out[j] = " "

    n = len(text)
    i = 0
    line = 1
    while i < n:
        c = text[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue

        # ---- comments ----------------------------------------------------
        if c == "/" and i + 1 < n:
            if text[i + 1] == "/":
                j = text.find("\n", i)
                j = n if j == -1 else j
                res.comments.append((line, text[i:j]))
                blank(i, j)
                i = j
                continue
            if text[i + 1] == "*":
                start_line = line
                depth = 1
                j = i + 2
                while j < n and depth > 0:
                    if text.startswith("/*", j):
                        depth += 1
                        j += 2
                    elif text.startswith("*/", j):
                        depth -= 1
                        j += 2
                    else:
                        if text[j] == "\n":
                            line += 1
                        j += 1
                if depth > 0:
                    res.errors.append((start_line, "unclosed block comment"))
                res.comments.append((start_line, text[i:j]))
                blank(i, j)
                i = j
                continue

        # ---- string-ish literals ----------------------------------------
        if c == '"':
            i, line = _scan_string(text, i, line, res, blank, raw_hashes=None)
            continue
        if c in "rb" and _raw_or_byte_prefix(text, i) is not None:
            kind, body_at, hashes = _raw_or_byte_prefix(text, i)
            if kind == "rawid":
                # r#ident — a raw identifier, not a string.
                j = body_at  # points at the ident start
                k = j
                while k < n and text[k] in _ID_CONT:
                    k += 1
                res.tokens.append(Token("id", text[j:k], line))
                i = k
                continue
            if kind == "raw":
                i, line = _scan_raw_string(text, i, body_at, hashes, line, res, blank)
                continue
            # kind == "byte": b"…" — normal escape rules
            i, line = _scan_string(text, body_at - 1, line, res, blank, raw_hashes=None)
            continue

        # ---- char literal vs lifetime -----------------------------------
        if c == "'":
            nxt = text[i + 1] if i + 1 < n else ""
            if nxt == "\\":
                j = i + 2
                if j < n:
                    if text[j] == "u":  # '\u{…}'
                        j = text.find("}", j)
                        j = n if j == -1 else j + 1
                    else:
                        j += 1
                if j < n and text[j] == "'":
                    blank(i + 1, j)
                    res.tokens.append(Token("char", "'\\.'", line))
                    i = j + 1
                else:
                    res.errors.append((line, "unclosed char literal"))
                    i = j
                continue
            if nxt in _ID_CONT:
                j = i + 1
                while j < n and text[j] in _ID_CONT:
                    j += 1
                if j < n and text[j] == "'" and j == i + 2:
                    # 'x' char literal (single ident char then closing quote)
                    blank(i + 1, j)
                    res.tokens.append(Token("char", "'.'", line))
                    i = j + 1
                else:
                    res.tokens.append(Token("life", text[i:j], line))
                    i = j
                continue
            if nxt == "'":
                res.errors.append((line, "empty char literal"))
                i += 2
                continue
            if nxt and nxt != "\n" and i + 2 < n and text[i + 2] == "'":
                # single non-ident char literal: ' ', '{', '"', '='
                blank(i + 1, i + 2)
                res.tokens.append(Token("char", "'.'", line))
                i += 3
                continue
            # Bare quote followed by punctuation: malformed
            res.errors.append((line, "stray ' (not a char literal or lifetime)"))
            i += 1
            continue

        # ---- identifiers / numbers --------------------------------------
        if c in _ID_START:
            j = i + 1
            while j < n and text[j] in _ID_CONT:
                j += 1
            res.tokens.append(Token("id", text[i:j], line))
            i = j
            continue
        if c.isdigit():
            j = i + 1
            while j < n and (text[j] in _ID_CONT):
                j += 1
            # fractional part: '.' followed by a digit (never '..' ranges)
            if j < n - 1 and text[j] == "." and text[j + 1].isdigit():
                j += 1
                while j < n and text[j] in _ID_CONT:
                    j += 1
            # exponent sign: 1e-6 / 1E+9 (the e was eaten by _ID_CONT)
            if j < n and text[j] in "+-" and text[j - 1] in "eE" and j >= 2 and text[i].isdigit():
                j += 1
                while j < n and text[j].isdigit() or (j < n and text[j] == "_"):
                    j += 1
            res.tokens.append(Token("num", text[i:j], line))
            i = j
            continue

        # ---- punctuation -------------------------------------------------
        for comp in _COMPOUND:
            if text.startswith(comp, i):
                # '..=' extends '..'
                if comp == ".." and text.startswith("..=", i):
                    comp = "..="
                res.tokens.append(Token("punct", comp, line))
                i += len(comp)
                break
        else:
            res.tokens.append(Token("punct", c, line))
            i += 1

    res.masked = "".join(out)
    return res


def _raw_or_byte_prefix(text: str, i: int):
    """Classify a possible r"/r#"/br#"/b" prefix at i.

    Returns (kind, body_start, hashes) where kind is 'raw' (raw string),
    'byte' (b"…"), or 'rawid' (r#ident), else None.  body_start points just
    past the opening quote (or at the ident for rawid).
    """
    n = len(text)
    j = i
    if text[j] == "b":
        j += 1
        if j < n and text[j] == "r":
            j += 1
            hashes = 0
            while j < n and text[j] == "#":
                hashes += 1
                j += 1
            if j < n and text[j] == '"':
                return ("raw", j + 1, hashes)
            return None
        if j < n and text[j] == '"':
            return ("byte", j + 1, 0)
        return None
    if text[j] == "r":
        j += 1
        hashes = 0
        while j < n and text[j] == "#":
            hashes += 1
            j += 1
        if j < n and text[j] == '"':
            return ("raw", j + 1, hashes)
        if hashes == 1 and j < n and text[j] in _ID_START:
            return ("rawid", j, 0)
        return None
    return None


def _scan_string(text, i, line, res, blank, raw_hashes):
    """Scan a plain/byte string starting at the quote char index i (or the
    char before body for byte strings). Returns (next_i, line)."""
    n = len(text)
    start_line = line
    # i points at the opening '"' for plain strings; for byte strings the
    # caller passes body_at-1 which is also the '"'.
    j = i + 1
    while j < n:
        ch = text[j]
        if ch == "\\":
            j += 2
            continue
        if ch == "\n":
            line += 1
            j += 1
            continue
        if ch == '"':
            blank(i + 1, j)
            res.tokens.append(Token("str", '"…"', start_line))
            return j + 1, line
        j += 1
    res.errors.append((start_line, "unclosed string literal"))
    blank(i + 1, n)
    res.tokens.append(Token("str", '"…"', start_line))
    return n, line


def _scan_raw_string(text, i, body_at, hashes, line, res, blank):
    """Scan r#"…"# starting with body at body_at. Returns (next_i, line)."""
    n = len(text)
    start_line = line
    close = '"' + "#" * hashes
    j = text.find(close, body_at)
    if j == -1:
        res.errors.append((start_line, "unclosed raw string literal"))
        blank(body_at, n)
        res.tokens.append(Token("str", '"…"', start_line))
        return n, line
    line += text.count("\n", body_at, j)
    blank(body_at, j)
    res.tokens.append(Token("str", '"…"', start_line))
    return j + len(close), line


# ---------------------------------------------------------------------------
# delimiter balance
# ---------------------------------------------------------------------------

_OPEN = {"(": ")", "[": "]", "{": "}"}
_CLOSE = {v: k for k, v in _OPEN.items()}


def check_balance(lx: LexResult, path: str) -> List[dict]:
    """Exact (), [], {} balance over the token stream (comments and literal
    contents already stripped, so a brace in a string can never unbalance)."""
    findings = []
    stack: List[Tuple[str, int]] = []
    for t in lx.tokens:
        if t.kind != "punct":
            continue
        if t.text in _OPEN:
            stack.append((t.text, t.line))
        elif t.text in _CLOSE:
            if not stack:
                findings.append(
                    _f("balance", path, t.line, f"unmatched closing '{t.text}'")
                )
            else:
                o, oline = stack.pop()
                if _OPEN[o] != t.text:
                    findings.append(
                        _f(
                            "balance",
                            path,
                            t.line,
                            f"mismatched '{t.text}' closing '{o}' opened at line {oline}",
                        )
                    )
    for o, oline in stack:
        findings.append(_f("balance", path, oline, f"unclosed '{o}'"))
    for ln, msg in lx.errors:
        findings.append(_f("lexer", path, ln, msg))
    return findings


def _f(rule: str, path: str, line: int, message: str) -> dict:
    return {"rule": rule, "file": path, "line": line, "message": message}
