"""rustcheck — a compiler-independent static-analysis gate for the Rust tree.

Seven PRs of Rust shipped rustc-unverified (no toolchain in any container
so far); every session repeated a manual brace-balance + API-signature
audit.  This package automates that audit as a real analyzer that runs on
bare CPython (no cargo, no pip deps) and is wired in as
``scripts/check.sh lint-smoke``.

Passes (DESIGN.md §Static-Analysis):

* ``lexer``  — a real Rust lexer (line/block comments, string / raw-string /
  byte-string / char literals, lifetimes) feeding exact delimiter balance
  and unclosed-literal checks with file:line diagnostics.
* ``parser`` — per-file item indexer: fn signatures + arity, structs /
  enums / traits / impl blocks / consts / uses / macros, with cfg-attr and
  module-scope tracking.
* ``crate``  — crate assembly: ``mod x;`` wiring, orphan-file reachability,
  ``use crate::…`` path resolution against the indexed item tree,
  duplicate-item detection, call-site arity for crate-local functions, and
  trait-impl completeness.
* ``lints``  — targeted lints encoding bugs this repo has actually hit:
  ``partial_cmp(..).unwrap()`` (the PR-3 NaN panic class), ``unsafe``
  without a ``// SAFETY:`` line, SIMD kernel tables whose fields drift from
  the scalar reference table, and nondeterminism sources outside the
  sanctioned ``net/mod.rs`` seam.

Entry point: ``python3 scripts/rustcheck [--strict] [--json]`` (see
``driver.py``), or ``run_repo(root)`` from Python.

What rustcheck can and cannot prove is documented in DESIGN.md
§Static-Analysis — it is a gate against the defect classes above, not a
replacement for rustc: no type checking, no borrow checking, no trait
resolution beyond name/arity matching.
"""

__version__ = "1.0.0"

from .driver import run_repo, main  # noqa: F401  (public API)
