"""Repo driver: crate discovery, lint scope, allowlist, CLI.

Crates analyzed:

* ``rust/src/lib.rs``   — the ``hyena`` library crate (module graph crawled),
* ``rust/src/main.rs``  — the binary crate (``use hyena::…`` resolves against
  the library's indexed item tree),
* every file in ``rust/tests``, ``benches``, ``examples`` — standalone crate
  roots with the same extern resolution,
* ``rust/vendor/*/src/lib.rs`` — vendored crates, crawled so library paths
  into them resolve; structural findings inside vendor are reported too.

Lint scope (partial_cmp / unsafe-SAFETY / kernel parity / nondeterminism) is
the first-party tree only: ``rust/src``, ``rust/tests``, ``benches``,
``examples`` — vendor code is indexed for resolution, not lint-audited.

Allowlist: ``scripts/rustcheck/allowlist.txt``; each entry is
``rule | path-glob | message-substring | justification`` and suppresses
matching findings (they are still reported under "allowlisted" in JSON).
"""

import argparse
import fnmatch
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from .crate import Crate, _f
from .lexer import check_balance, lex
from .lints import (
    lint_kernel_parity,
    lint_nondeterminism,
    lint_partial_cmp,
    lint_unsafe_safety,
)

_KERNELS_DIR = "rust/src/backend/native/kernels"


def _default_root() -> Path:
    return Path(__file__).resolve().parents[2]


def _rel(root: Path, p: Path) -> str:
    return p.resolve().relative_to(root.resolve()).as_posix()


def _scope_dirs(root: Path) -> List[Path]:
    dirs = []
    for cand in ("rust/src", "rust/tests", "rust/benches", "benches",
                 "rust/examples", "examples"):
        d = root / cand
        if d.is_dir():
            dirs.append(d)
    return dirs


def _standalone_roots(root: Path) -> List[Path]:
    out = []
    for cand in ("rust/tests", "rust/benches", "benches", "rust/examples",
                 "examples"):
        d = root / cand
        if d.is_dir():
            out.extend(sorted(d.glob("*.rs")))
    return out


def run_repo(root: Optional[Path] = None,
             allowlist_path: Optional[Path] = None) -> dict:
    """Run every pass; returns {"findings": […], "allowlisted": […]}."""
    root = Path(root) if root else _default_root()
    findings: List[dict] = []

    # -- crates -------------------------------------------------------------
    externs: Dict[str, Crate] = {}
    vendor_crates: List[Crate] = []
    for vend in sorted((root / "rust" / "vendor").glob("*/src/lib.rs")):
        name = vend.parent.parent.name.replace("-", "_")
        c = Crate(name, vend, root)
        externs[name] = c
        vendor_crates.append(c)

    lib = None
    lib_root = root / "rust" / "src" / "lib.rs"
    if lib_root.is_file():
        lib = Crate("hyena", lib_root, root, externs=externs)
        findings.extend(lib.run_checks())
    bin_c = None
    bin_root = root / "rust" / "src" / "main.rs"
    if bin_root.is_file():
        bin_externs = dict(externs)
        if lib is not None:
            bin_externs["hyena"] = lib
        bin_c = Crate("hyena-bin", bin_root, root, externs=bin_externs)
        findings.extend(bin_c.run_checks())
    for c in vendor_crates:
        findings.extend(c.run_checks())
    for sroot in _standalone_roots(root):
        ext = dict(externs)
        if lib is not None:
            ext["hyena"] = lib
        c = Crate(sroot.stem, sroot, root, externs=ext)
        findings.extend(c.run_checks())

    # -- orphan files -------------------------------------------------------
    visited = set()
    for c in [lib, bin_c] + vendor_crates:
        if c is not None:
            visited.update(c.files)
    src = root / "rust" / "src"
    orphans = []
    if src.is_dir():
        for f in sorted(src.rglob("*.rs")):
            rel = _rel(root, f)
            if rel not in visited:
                orphans.append(rel)
                findings.append(_f(
                    "orphan-file", rel, 1,
                    "file is not reachable from lib.rs or main.rs "
                    "via any `mod` chain",
                ))

    # -- lints over the first-party tree ------------------------------------
    kernel_masked: Dict[str, str] = {}
    for d in _scope_dirs(root):
        for f in sorted(d.rglob("*.rs")):
            rel = _rel(root, f)
            try:
                text = f.read_text(encoding="utf-8")
            except OSError as e:
                findings.append(_f("io", rel, 1, f"cannot read file: {e}"))
                continue
            lx = lex(text, rel)
            if rel in orphans:
                # orphans were never loaded by a crate: balance-check here
                findings.extend(check_balance(lx, rel))
            findings.extend(lint_partial_cmp(lx.masked, rel))
            findings.extend(lint_unsafe_safety(lx, text, rel))
            findings.extend(lint_nondeterminism(lx.masked, rel))
            if rel.startswith(_KERNELS_DIR):
                kernel_masked[rel] = lx.masked
    findings.extend(lint_kernel_parity(kernel_masked))

    # -- allowlist ----------------------------------------------------------
    allow = _load_allowlist(
        allowlist_path or (Path(__file__).resolve().parent / "allowlist.txt")
    )
    kept, allowed = [], []
    for fd in findings:
        if _allowlisted(fd, allow):
            allowed.append(fd)
        else:
            kept.append(fd)
    kept.sort(key=lambda fd: (fd["file"], fd["line"], fd["rule"]))
    allowed.sort(key=lambda fd: (fd["file"], fd["line"], fd["rule"]))
    return {"findings": kept, "allowlisted": allowed}


def _load_allowlist(path: Path) -> List[Tuple[str, str, str]]:
    entries = []
    if not path.is_file():
        return entries
    for raw in path.read_text(encoding="utf-8").splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        parts = [p.strip() for p in line.split("|")]
        if len(parts) < 4 or not parts[3]:
            # malformed or unjustified entries do not suppress anything
            continue
        entries.append((parts[0], parts[1], parts[2]))
    return entries


def _allowlisted(fd: dict, allow: List[Tuple[str, str, str]]) -> bool:
    for rule, glob, sub in allow:
        if rule != "*" and rule != fd["rule"]:
            continue
        if glob and not fnmatch.fnmatch(fd["file"], glob):
            continue
        if sub and sub not in fd["message"]:
            continue
        return True
    return False


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="rustcheck",
        description="compiler-independent static-analysis gate for the Rust tree",
    )
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: two levels above this package)")
    ap.add_argument("--allowlist", type=Path, default=None,
                    help="allowlist file (default: scripts/rustcheck/allowlist.txt)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero if any unallowlisted finding remains")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable JSON on stdout")
    args = ap.parse_args(argv)

    res = run_repo(args.root, args.allowlist)
    findings, allowed = res["findings"], res["allowlisted"]

    if args.json:
        print(json.dumps({
            "findings": findings,
            "allowlisted": allowed,
            "summary": {
                "findings": len(findings),
                "allowlisted": len(allowed),
                "by_rule": _by_rule(findings),
            },
        }, indent=2))
    else:
        for fd in findings:
            print(f"{fd['file']}:{fd['line']}: [{fd['rule']}] {fd['message']}")
        tail = f"rustcheck: {len(findings)} finding(s)"
        if allowed:
            tail += f", {len(allowed)} allowlisted"
        print(tail)

    if args.strict and findings:
        return 1
    return 0


def _by_rule(findings: List[dict]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for fd in findings:
        out[fd["rule"]] = out.get(fd["rule"], 0) + 1
    return out


if __name__ == "__main__":
    sys.exit(main())
