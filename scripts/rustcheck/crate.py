"""Crate assembly and the cross-file passes.

Starting from a crate root (``lib.rs``, ``main.rs``, or a standalone
test/bench/example file), follows every ``mod x;`` declaration to its file
(``x.rs`` or ``x/mod.rs``), builds per-module namespaces (items + child
modules + resolved ``pub use`` re-exports, to a fixpoint), and then runs:

* **mod-unresolved**   — a ``mod x;`` with no backing file,
* **use-unresolved**   — a ``use`` path that does not resolve against the
  indexed item tree (``crate::``/``self::``/``super::`` and the local crates
  ``hyena``/``anyhow``/``xla``; ``std``-and-friends are trusted),
* **duplicate**        — two ungated (no ``#[cfg]``) definitions of the same
  name in the same module namespace,
* **arity**            — a call site of a crate-local function whose argument
  count disagrees with the definition (closure-bearing and generic-heavy
  argument lists are skipped as uncountable),
* **trait-impl**       — an ``impl Trait for Type`` of a crate-local trait
  that neither defines nor inherits a required method,
* **struct-lit-field** — a struct literal or struct pattern
  ``Type { field: … }`` spelling a field that does not exist on the
  resolved crate-local struct definition (cfg-gated defs and anything
  that does not resolve to a named-field struct are skipped).

Resolution is deliberately lenient where the analyzer cannot be sure
(glob imports open a namespace, unknown extern crates are trusted, methods
not found on a type are assumed derived/blanket) — findings fire only on
facts the index can actually prove wrong.
"""

from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from .lexer import lex, check_balance
from .parser import Fn, TypeItem, index_file

EXTERNAL_CRATES = {"std", "core", "alloc", "proc_macro", "test"}


def _f(rule: str, path: str, line: int, message: str) -> dict:
    return {"rule": rule, "file": str(path), "line": line, "message": message}


@dataclass
class Mod:
    path: Tuple[str, ...]
    # name -> list of (item, kind) — first entry wins for lookup, the rest
    # feed the duplicate check.  kind: fn | type | value | mod
    values: Dict[str, List] = field(default_factory=list)
    types: Dict[str, List] = field(default_factory=dict)
    uses: List = field(default_factory=list)
    imports: Dict[str, tuple] = field(default_factory=dict)
    has_glob: bool = False  # any glob import: local lookups become open
    pub_glob: bool = False  # pub glob re-export: defs become open

    def __post_init__(self):
        if not isinstance(self.values, dict):
            self.values = {}


class Crate:
    def __init__(self, name: str, root_file: Path, repo_root: Path,
                 externs: Optional[Dict[str, "Crate"]] = None):
        self.name = name
        self.repo = repo_root
        self.root_file = root_file
        self.externs = dict(externs or {})
        self.files: Dict[str, object] = {}  # rel path -> FileIndex
        self.file_mod: Dict[str, Tuple[str, ...]] = {}
        self.mods: Dict[Tuple[str, ...], Mod] = {}
        self.traits: Dict[str, dict] = {}  # name -> {required, provided}
        self.impls_by_type: Dict[str, List] = {}
        self.findings: List[dict] = []
        self._load(root_file, ())
        self._build_namespaces()
        self._resolve_reexports()
        self._resolve_imports()

    # -- loading --------------------------------------------------------------

    def _rel(self, p: Path) -> str:
        try:
            return p.resolve().relative_to(self.repo.resolve()).as_posix()
        except ValueError:
            return p.as_posix()

    def _load(self, file_path: Path, mod_path: Tuple[str, ...]) -> None:
        rel = self._rel(file_path)
        if rel in self.files:
            return
        try:
            text = file_path.read_text(encoding="utf-8")
        except OSError as e:
            self.findings.append(_f("io", rel, 1, f"cannot read file: {e}"))
            return
        lx = lex(text, rel)
        self.findings.extend(check_balance(lx, rel))
        idx = index_file(lx, rel)
        self.files[rel] = idx
        self.file_mod[rel] = mod_path
        self._ensure(mod_path)
        for m in idx.mods:
            child = mod_path + m.module + (m.name,)
            self._ensure(child)
            if m.inline:
                continue
            resolved = self._mod_file(file_path, mod_path, m)
            if resolved is None:
                self.findings.append(_f(
                    "mod-unresolved", rel, m.line,
                    f"`mod {m.name};` has no backing file "
                    f"({m.name}.rs or {m.name}/mod.rs next to {file_path.name})",
                ))
            else:
                self._load(resolved, child)

    def _mod_file(self, file_path: Path, mod_path, m) -> Optional[Path]:
        base = file_path.parent
        if file_path.name not in ("lib.rs", "main.rs", "mod.rs") and mod_path:
            base = base / file_path.stem
        for seg in m.module:  # mod declared inside an inline module
            base = base / seg
        for cand in (base / f"{m.name}.rs", base / m.name / "mod.rs"):
            if cand.is_file():
                return cand
        return None

    def _ensure(self, path: Tuple[str, ...]) -> Mod:
        for k in range(len(path) + 1):
            p = path[:k]
            if p not in self.mods:
                self.mods[p] = Mod(p)
        return self.mods[path]

    # -- namespaces -----------------------------------------------------------

    def _add(self, mod: Tuple[str, ...], ns: str, name: str, item, kind: str):
        m = self._ensure(mod)
        table = m.values if ns == "value" else m.types
        table.setdefault(name, []).append((item, kind))

    def _build_namespaces(self) -> None:
        for rel, idx in self.files.items():
            base = self.file_mod[rel]
            for fn in idx.fns:
                if fn.container is None:
                    self._add(base + fn.module, "value", fn.name, fn, "fn")
            for t in idx.types:
                mod = base + t.module
                self._ensure(mod)
                self._add(mod, "type", t.name, t, "type")
                if t.tuple_arity is not None:
                    self._add(mod, "value", t.name, t, "type")  # tuple ctor
                if t.kind == "trait":
                    src = idx.traits.get(t.name, {"required": {}, "provided": {}})
                    tgt = self.traits.setdefault(
                        t.name, {"required": {}, "provided": {}, "line": t.line}
                    )
                    tgt["required"].update(src["required"])
                    tgt["provided"].update(src["provided"])
            for v in idx.values:
                if v.container is not None:
                    continue  # assoc const of an impl/trait, not a module item
                self._add(base + v.module, "value", v.name, v, "value")
                if v.kind == "macro" and v.exported and (base + v.module):
                    # #[macro_export] hoists the macro to the crate root
                    self._add((), "value", v.name, v, "value")
            for m in idx.mods:
                self._add(base + m.module, "type", m.name,
                          base + m.module + (m.name,), "mod")
            for imp in idx.impls:
                self.impls_by_type.setdefault(imp.type_name, []).append(imp)
            for u in idx.uses:
                mod = self._ensure(base + u.module)
                mod.uses.append((u, rel))
                if u.segments[-1] == "*":
                    mod.has_glob = True
                    if u.is_pub:
                        mod.pub_glob = True

    def _resolve_reexports(self) -> None:
        # pub use chains: resolve to a fixpoint so `pub use a::b; pub use
        # crate::x::b as c;` style laddering lands in defs.
        for _ in range(5):
            changed = False
            for mod in list(self.mods.values()):
                for u, _rel in mod.uses:
                    if not u.is_pub or u.segments[-1] == "*":
                        continue
                    name = u.alias or u.segments[-1]
                    if name in mod.values or name in mod.types:
                        continue
                    res = self.resolve(mod.path, u.segments, quiet=True)
                    if res[0] in ("fn", "value"):
                        self._add(mod.path, "value", name, res[1], res[0])
                        changed = True
                    elif res[0] == "type":
                        self._add(mod.path, "type", name, res[1], "type")
                        if getattr(res[1], "tuple_arity", None) is not None:
                            self._add(mod.path, "value", name, res[1], "type")
                        changed = True
                    elif res[0] == "mod":
                        self._add(mod.path, "type", name, res[2], "mod")
                        changed = True
            if not changed:
                break

    def _resolve_imports(self) -> None:
        for mod in self.mods.values():
            for u, rel in mod.uses:
                leaf = u.segments[-1]
                res = self.resolve(mod.path, u.segments, quiet=True)
                if res[0] == "missing":
                    self.findings.append(_f(
                        "use-unresolved", rel, u.line,
                        f"`use {'::'.join(u.segments)}` does not resolve: {res[1]}",
                    ))
                    continue
                if leaf == "*":
                    continue
                name = u.alias or leaf
                if name == "self" and len(u.segments) >= 2:
                    name = u.segments[-2]
                mod.imports.setdefault(name, res)

    # -- path resolution ------------------------------------------------------

    def lookup(self, mod_path: Tuple[str, ...], name: str, ns: str):
        """Name lookup inside one module: defs first, then imports."""
        m = self.mods.get(mod_path)
        if m is None:
            return ("unknown",)
        table = m.values if ns == "value" else m.types
        if name in table:
            item, kind = table[name][0]
            if kind == "mod":
                return ("mod", self, item)
            return (kind, item)
        if name in m.imports:
            return m.imports[name]
        if m.has_glob or m.pub_glob:
            return ("unknown",)
        return ("absent",)

    def resolve(self, cur_mod: Tuple[str, ...], segments: Tuple[str, ...],
                quiet: bool = False):
        """Resolve a `use`/call path. Returns one of:
        ("fn", Fn) | ("type", TypeItem) | ("value", item) |
        ("mod", crate, path) | ("variant", enum, name) | ("method", Fn) |
        ("unknown",) | ("external",) | ("missing", reason)."""
        segs = list(segments)
        crate: Crate = self
        base = cur_mod
        first = segs[0]
        if first == "crate":
            base = ()
            segs = segs[1:]
        elif first == "self" and len(segs) > 1:
            segs = segs[1:]
        elif first == "super":
            while segs and segs[0] == "super":
                if not base:
                    return ("missing", "`super` above the crate root")
                base = base[:-1]
                segs = segs[1:]
        elif first == self.name:
            base = ()
            segs = segs[1:]
        elif first in self.externs:
            crate = self.externs[first]
            base = ()
            segs = segs[1:]
        elif first in EXTERNAL_CRATES:
            return ("external",)
        else:
            # relative: first segment must be visible in the current module
            probe = crate.lookup(base, first, "type")
            if probe[0] == "absent":
                probe = crate.lookup(base, first, "value")
            if probe[0] == "mod":
                crate, base = probe[1], probe[2]
                segs = segs[1:]
            elif probe[0] in ("fn", "value") and len(segs) == 1:
                return probe
            elif probe[0] == "type":
                return crate._assoc(probe[1], segs[1:])
            elif probe[0] == "absent":
                # unknown extern crate (edition-2018 path) — trust it
                return ("external",)
            else:
                return ("unknown",)
            if not segs:
                return ("mod", crate, base)
        # walk the remaining segments through child modules
        while segs:
            seg = segs[0]
            if seg == "self" and len(segs) == 1:
                return ("mod", crate, base)
            if seg == "*" and len(segs) == 1:
                return ("mod", crate, base)
            hit = crate.lookup(base, seg, "type")
            if hit[0] == "mod":
                crate, base = hit[1], hit[2]
                segs = segs[1:]
                continue
            if hit[0] == "type":
                return crate._assoc(hit[1], segs[1:])
            if hit[0] in ("unknown",):
                return ("unknown",)
            # not a module/type: maybe a value leaf
            if len(segs) == 1:
                vhit = crate.lookup(base, seg, "value")
                if vhit[0] in ("fn", "value", "type"):
                    return vhit
                if vhit[0] == "unknown":
                    return ("unknown",)
                mod_name = "::".join(("crate",) + base) if crate is self else crate.name
                return ("missing", f"`{seg}` not found in `{mod_name or 'crate'}`")
            mod_name = "::".join(("crate",) + base) if crate is self else crate.name
            return ("missing", f"`{seg}` is not a module in `{mod_name or 'crate'}`")
        return ("mod", crate, base)

    def _assoc(self, t: TypeItem, rest: List[str]):
        """Resolve `Type::rest…` — enum variants and impl/trait methods."""
        if not rest:
            return ("type", t)
        if len(rest) > 1:
            return ("unknown",)
        name = rest[0]
        if t.kind == "enum":
            if name == "*":
                return ("type", t)
            if name in t.variants:
                return ("variant", t, name)
        m = self.find_method(t.name, name)
        if m is not None:
            return ("method", m)
        # derives, blanket impls, assoc consts: not indexed — trust it
        return ("unknown",)

    def find_method(self, type_name: str, meth: str) -> Optional[Fn]:
        for imp in self.impls_by_type.get(type_name, []):
            if meth in imp.methods:
                return imp.methods[meth]
        # provided methods inherited from crate-local trait impls
        for imp in self.impls_by_type.get(type_name, []):
            if imp.trait_name and imp.trait_name in self.traits:
                tr = self.traits[imp.trait_name]
                if meth in tr["provided"]:
                    return tr["provided"][meth]
                if meth in tr["required"]:
                    return tr["required"][meth]
        return None

    # -- cross-file checks ----------------------------------------------------

    def check_duplicates(self) -> List[dict]:
        out = []
        for mod in self.mods.values():
            for ns_name, table in (("value", mod.values), ("type", mod.types)):
                for name, entries in table.items():
                    defined = [
                        it for it, kind in entries
                        if kind in ("fn", "type", "value")
                        and getattr(it, "cfg", "x") is None
                    ]
                    if len(defined) > 1:
                        first, second = defined[0], defined[1]
                        out.append(_f(
                            "duplicate",
                            self._item_file(second), second.line,
                            f"duplicate {ns_name}-namespace definition of "
                            f"`{name}` (first at "
                            f"{self._item_file(first)}:{first.line})",
                        ))
        # duplicate methods within impls of the same (type, trait) pair
        seen: Dict[tuple, Fn] = {}
        for tname, imps in self.impls_by_type.items():
            for imp in imps:
                if imp.cfg is not None:
                    continue
                for mname, fn in imp.methods.items():
                    if fn.cfg is not None:
                        continue
                    key = (tname, imp.trait_name, mname)
                    if key in seen:
                        out.append(_f(
                            "duplicate", self._item_file(fn), fn.line,
                            f"duplicate method `{tname}::{mname}` (first at "
                            f"{self._item_file(seen[key])}:{seen[key].line})",
                        ))
                    else:
                        seen[key] = fn
        return out

    def _item_file(self, item) -> str:
        mod = getattr(item, "module", ())
        for rel, idx in self.files.items():
            if item in idx.fns or item in idx.types or item in idx.values:
                return rel
        del mod
        return self._rel(self.root_file)

    def check_calls(self) -> List[dict]:
        out = []
        for rel, idx in self.files.items():
            base = self.file_mod[rel]
            for call in idx.calls:
                if call.arity is None:
                    continue
                res = self.resolve(base + call.module, call.segments, quiet=True)
                expected = None
                label = "::".join(call.segments)
                if res[0] == "fn":
                    fn = res[1]
                    expected = fn.arity + (1 if fn.has_self else 0)
                elif res[0] == "method":
                    fn = res[1]
                    expected = fn.arity + (1 if fn.has_self else 0)
                elif res[0] == "variant":
                    enum, vname = res[1], res[2]
                    va = enum.variants.get(vname)
                    if va is None:
                        continue
                    expected = va
                elif res[0] == "type":
                    t = res[1]
                    if t.tuple_arity is None:
                        continue
                    expected = t.tuple_arity
                else:
                    continue
                if expected != call.arity:
                    out.append(_f(
                        "arity", rel, call.line,
                        f"call of `{label}` passes {call.arity} argument(s), "
                        f"definition takes {expected}",
                    ))
        return out

    def check_trait_impls(self) -> List[dict]:
        out = []
        for tname, imps in self.impls_by_type.items():
            for imp in imps:
                if not imp.trait_name:
                    continue
                tr = self.traits.get(imp.trait_name)
                if tr is None:
                    continue  # std / vendored trait: not ours to judge
                missing = sorted(set(tr["required"]) - set(imp.methods))
                if missing:
                    rel = self._impl_file(imp)
                    out.append(_f(
                        "trait-impl", rel, imp.line,
                        f"`impl {imp.trait_name} for {tname}` is missing "
                        f"required method(s): {', '.join(missing)}",
                    ))
        return out

    def _impl_file(self, imp) -> str:
        for rel, idx in self.files.items():
            if imp in idx.impls:
                return rel
        return self._rel(self.root_file)

    def check_struct_lits(self) -> List[dict]:
        out = []
        for rel, idx in self.files.items():
            base = self.file_mod[rel]
            for lit in idx.lits:
                if lit.segments[-1] == "Self":
                    continue  # receiver type unknown without impl context
                res = self.resolve(base + lit.module, lit.segments, quiet=True)
                if res[0] != "type":
                    continue
                t = res[1]
                if t.kind not in ("struct", "union") or t.fields is None:
                    continue
                if t.cfg is not None:
                    continue  # a cfg-twin definition may own the field
                known = set(t.fields)
                for fname in lit.fields:
                    if fname not in known:
                        out.append(_f(
                            "struct-lit-field", rel, lit.line,
                            f"struct literal `{'::'.join(lit.segments)}` uses "
                            f"unknown field `{fname}` (fields of `{t.name}` at "
                            f"{self._item_file(t)}:{t.line}: "
                            f"{', '.join(t.fields) or '<none>'})",
                        ))
        return out

    def run_checks(self) -> List[dict]:
        out = list(self.findings)
        out.extend(self.check_duplicates())
        out.extend(self.check_calls())
        out.extend(self.check_trait_impls())
        out.extend(self.check_struct_lits())
        return out
