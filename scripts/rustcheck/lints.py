"""Targeted lints encoding defect classes this repo has actually hit.

All text matching runs on the lexer's *masked* source (comment bodies and
literal contents blanked), so a pattern can never fire inside a string or a
comment.  The SAFETY lint additionally consumes the lexer's comment list and
the raw source lines.

Lints:

* **partial-cmp-unwrap** — ``.partial_cmp(..).unwrap()`` / ``.expect(..)``:
  the PR-3 NaN panic class.  ``f32::total_cmp`` is the sanctioned spelling.
* **unsafe-no-safety**   — an ``unsafe`` block / fn / impl with no
  ``// SAFETY:`` comment on the same line or immediately above (attributes,
  blank lines and further ``unsafe`` lines are transparent; ``/// # Safety``
  doc sections also satisfy the rule for ``unsafe fn``).
* **kernel-parity**      — a ``Kernels { … }`` dispatch table in an
  arch-gated kernel file whose field set drifts from the scalar reference
  table in ``kernels/mod.rs``.
* **nondeterminism**     — wall-clock / OS-entropy sources
  (``SystemTime::now``, ``thread_rng``, ``from_entropy``, ``rand::random``,
  ``getrandom``) anywhere in ``rust/src`` outside the sanctioned seams
  (``net/mod.rs`` and ``obs/clock.rs``).  Reproducibility is a core paper
  claim; randomness must flow from seeded ``util::rng`` and wall-clock
  reads through ``obs::clock``.
"""

import re
from typing import Dict, List, Optional, Tuple

from .lexer import LexResult


def _f(rule: str, path: str, line: int, message: str) -> dict:
    return {"rule": rule, "file": str(path), "line": line, "message": message}


# ---------------------------------------------------------------------------
# partial_cmp().unwrap()
# ---------------------------------------------------------------------------

_PARTIAL_CMP = re.compile(
    r"\.\s*partial_cmp\s*\([^()]*\)\s*\.\s*(unwrap|expect)\s*\(",
    re.S,
)


def lint_partial_cmp(masked: str, path: str) -> List[dict]:
    out = []
    for m in _PARTIAL_CMP.finditer(masked):
        line = masked.count("\n", 0, m.start()) + 1
        out.append(_f(
            "partial-cmp-unwrap", path, line,
            f"`.partial_cmp(..).{m.group(1)}()` panics on NaN — "
            "use `f32::total_cmp` (or handle the None)",
        ))
    return out


# ---------------------------------------------------------------------------
# unsafe without SAFETY
# ---------------------------------------------------------------------------

_ATTR_LINE = re.compile(r"^\s*#\s*!?\s*\[")
_WALK_LIMIT = 12


def lint_unsafe_safety(lx: LexResult, raw: str, path: str) -> List[dict]:
    lines = raw.split("\n")
    # line -> all comment text starting or spanning that line
    comment_on: Dict[int, str] = {}
    for ln, text in lx.comments:
        span = text.count("\n") + 1
        for k in range(span):
            comment_on[ln + k] = comment_on.get(ln + k, "") + " " + text

    def line_is_transparent(ln: int) -> bool:
        if ln in comment_on:
            return True
        src = lines[ln - 1] if 0 < ln <= len(lines) else ""
        s = src.strip()
        return (
            not s
            or _ATTR_LINE.match(src) is not None
            or "unsafe" in src
        )

    def has_safety_near(ln: int) -> bool:
        if "SAFETY" in comment_on.get(ln, "") or "# Safety" in comment_on.get(ln, ""):
            return True
        k = ln - 1
        steps = 0
        while k > 0 and steps < _WALK_LIMIT and line_is_transparent(k):
            c = comment_on.get(k, "")
            if "SAFETY" in c or "# Safety" in c:
                return True
            k -= 1
            steps += 1
        return False

    out = []
    seen_lines = set()
    toks = lx.tokens
    for i, t in enumerate(toks):
        if t.kind != "id" or t.text != "unsafe" or t.line in seen_lines:
            continue
        seen_lines.add(t.line)
        nxt = toks[i + 1] if i + 1 < len(toks) else None
        what = "block"
        if nxt is not None and nxt.kind == "id":
            if nxt.text in ("fn", "impl", "trait", "extern"):
                what = nxt.text
        if not has_safety_near(t.line):
            out.append(_f(
                "unsafe-no-safety", path, t.line,
                f"`unsafe` {what} without a `// SAFETY:` comment "
                "(same line or immediately above)",
            ))
    return out


# ---------------------------------------------------------------------------
# kernel dispatch-table parity
# ---------------------------------------------------------------------------

def _kernels_literals(masked: str) -> List[Tuple[int, List[str]]]:
    """Find every `Kernels { … }` region and return (line, field names).

    Matches both struct literals (`Kernels { axpy: scalar::axpy, … }`) and
    the struct definition itself (`pub struct Kernels { pub axpy: fn(…), …}`)
    — both carry the authoritative field set.
    """
    out = []
    for m in re.finditer(r"\bKernels\s*\{", masked):
        # Only the struct definition (`struct Kernels {`) and value tables
        # (`= Kernels {`) carry a field set; `impl`/`for`/return-position
        # `… -> &Kernels {` matches open ordinary blocks.
        prefix = masked[:m.start()].rstrip()
        if not (prefix.endswith("=") or re.search(r"\bstruct\s*$", prefix)):
            continue
        start = m.end() - 1
        depth = 0
        j = start
        while j < len(masked):
            if masked[j] == "{":
                depth += 1
            elif masked[j] == "}":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        # `->` in fn-pointer field types would skew angle-depth tracking
        body = masked[start + 1:j].replace("->", "  ")
        line = masked.count("\n", 0, m.start()) + 1
        fields = []
        # split at top-level commas (fn-pointer types carry parens/commas)
        depth = 0
        piece = []
        pieces = []
        for ch in body:
            if ch in "([{<":
                depth += 1
            elif ch in ")]}>":
                depth -= 1
            if ch == "," and depth == 0:
                pieces.append("".join(piece))
                piece = []
            else:
                piece.append(ch)
        pieces.append("".join(piece))
        for p in pieces:
            p = p.strip()
            if not p or p.startswith(".."):  # struct-update syntax
                continue
            fm = re.match(r"(?:pub(?:\s*\([^)]*\))?\s+)?([A-Za-z_][A-Za-z0-9_]*)\s*(?::|$)", p)
            if fm:
                fields.append(fm.group(1))
        out.append((line, fields))
    return out


def lint_kernel_parity(kernel_files: Dict[str, str]) -> List[dict]:
    """kernel_files: rel path -> masked text for every file in the kernels
    dir.  The reference field set is the first `Kernels {` region in mod.rs
    (the struct definition / SCALAR table); every other table must carry
    exactly the same fields."""
    out = []
    ref_fields: Optional[List[str]] = None
    ref_where = None
    mod_path = next((p for p in kernel_files if p.endswith("mod.rs")), None)
    if mod_path is not None:
        lits = _kernels_literals(kernel_files[mod_path])
        if lits:
            ref_where = f"{mod_path}:{lits[0][0]}"
            ref_fields = lits[0][1]
    if ref_fields is None:
        return out
    ref_set = set(ref_fields)
    for path, masked in sorted(kernel_files.items()):
        for line, fields in _kernels_literals(masked):
            if path == mod_path and f"{path}:{line}" == ref_where:
                continue
            got = set(fields)
            missing = sorted(ref_set - got)
            extra = sorted(got - ref_set)
            if missing:
                out.append(_f(
                    "kernel-parity", path, line,
                    f"`Kernels` table is missing field(s) {missing} present "
                    f"in the scalar reference table ({ref_where}) — every "
                    "arch-gated kernel needs a scalar counterpart",
                ))
            if extra:
                out.append(_f(
                    "kernel-parity", path, line,
                    f"`Kernels` table has field(s) {extra} absent from the "
                    f"scalar reference table ({ref_where})",
                ))
    return out


# ---------------------------------------------------------------------------
# nondeterminism outside the sanctioned seam
# ---------------------------------------------------------------------------

_NONDET = re.compile(
    r"\b(SystemTime\s*::\s*now|thread_rng|from_entropy|rand\s*::\s*random|getrandom)\b"
)
_NONDET_SEAMS = frozenset({
    "rust/src/net/mod.rs",      # Retry-After wall-clock, net entropy
    "rust/src/obs/clock.rs",    # telemetry epoch timestamps (obs::clock)
})


def lint_nondeterminism(masked: str, path: str) -> List[dict]:
    p = str(path).replace("\\", "/")
    if not p.startswith("rust/src/"):
        return []  # tests/benches/examples may use wall-clock freely
    if p in _NONDET_SEAMS:
        return []  # the sanctioned seams
    out = []
    for m in _NONDET.finditer(masked):
        line = masked.count("\n", 0, m.start()) + 1
        out.append(_f(
            "nondeterminism", path, line,
            f"`{m.group(1)}` outside the sanctioned seams "
            "(net/mod.rs, obs/clock.rs) — route randomness through seeded "
            "util::rng and clocks through obs::clock",
        ))
    return out
