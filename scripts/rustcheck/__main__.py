"""Allow `python3 scripts/rustcheck [...]` to run the analyzer directly."""

import sys
from pathlib import Path

if __package__ in (None, ""):
    # invoked as `python3 scripts/rustcheck` — make the package importable
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from rustcheck.driver import main
else:
    from .driver import main

sys.exit(main())
