"""Per-file item indexer over the token stream.

Walks a file's tokens with an explicit scope stack (module / impl / trait /
body) and records every item the cross-file passes need:

* functions with arity, ``self`` receivers, cfg attributes,
* structs (tuple arity, named-field lists), enums (+ variants), traits
  (required vs provided methods), type aliases, consts/statics,
  ``macro_rules!`` names,
* impl blocks (inherent and ``impl Trait for Type``) with their methods,
* ``mod x;`` declarations and inline ``mod x { … }`` scopes,
* ``use`` trees (groups, globs, renames, ``pub use`` re-exports),
* call sites ``path::to::f(…)`` with exact top-level argument counts,
* struct literals/patterns ``Path::To::Type { field: …, field, .. }`` with
  the field names they spell (brace regions that do not parse as a field
  list — e.g. the block of ``if x == E::V { … }`` — are never recorded).

Bodies are opaque except for brace tracking, call-site collection and
struct-literal collection, so locals never pollute the item index.
"""

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from .lexer import LexResult, Token

_ITEM_SCOPES = ("mod", "impl", "trait", "extern")


@dataclass
class Fn:
    name: str
    arity: int  # parameter count, excluding any self receiver
    has_self: bool
    line: int
    is_pub: bool
    cfg: Optional[str]  # raw #[cfg(…)] text, None if ungated
    module: Tuple[str, ...]  # inline-module path within the file
    container: Optional[str] = None  # impl/trait type name, None for free fns
    trait_of: Optional[str] = None  # trait name when inside `impl Trait for T`
    is_required_trait_method: bool = False  # trait method declared with `;`


@dataclass
class TypeItem:
    kind: str  # struct | enum | trait | type | union
    name: str
    line: int
    cfg: Optional[str]
    module: Tuple[str, ...]
    tuple_arity: Optional[int] = None  # struct X(a, b) constructor arity
    variants: dict = field(default_factory=dict)  # enum: name -> tuple arity|None
    fields: Optional[List[str]] = None  # struct/union named fields, else None


@dataclass
class ValueItem:
    kind: str  # const | static | macro
    name: str
    line: int
    cfg: Optional[str]
    module: Tuple[str, ...]
    container: Optional[str] = None  # impl/trait name for assoc consts
    exported: bool = False  # macro_rules! under #[macro_export]


@dataclass
class ModDecl:
    name: str
    line: int
    cfg: Optional[str]
    inline: bool
    module: Tuple[str, ...]  # parent inline-module path


@dataclass
class Use:
    segments: Tuple[str, ...]  # full path, leaf included ('*' for glob)
    alias: Optional[str]
    is_pub: bool
    line: int
    module: Tuple[str, ...]


@dataclass
class Impl:
    type_name: str
    trait_name: Optional[str]
    line: int
    cfg: Optional[str]
    module: Tuple[str, ...]
    methods: dict = field(default_factory=dict)  # name -> Fn


@dataclass
class Call:
    segments: Tuple[str, ...]
    arity: Optional[int]  # None when the args were too gnarly to count
    line: int
    module: Tuple[str, ...]
    in_body: bool


@dataclass
class StructLit:
    """A struct literal or struct pattern `Path::Type { fields… }`. Both
    forms demand that every spelled field exist on the struct definition,
    so one record feeds the existence check for either."""
    segments: Tuple[str, ...]
    fields: List[str]
    line: int
    module: Tuple[str, ...]


@dataclass
class FileIndex:
    path: str
    fns: List[Fn] = field(default_factory=list)
    types: List[TypeItem] = field(default_factory=list)
    values: List[ValueItem] = field(default_factory=list)
    mods: List[ModDecl] = field(default_factory=list)
    uses: List[Use] = field(default_factory=list)
    impls: List[Impl] = field(default_factory=list)
    calls: List[Call] = field(default_factory=list)
    lits: List[StructLit] = field(default_factory=list)
    traits: dict = field(default_factory=dict)  # name -> {"required": set, "provided": set}


@dataclass
class _Scope:
    kind: str  # mod | impl | trait | body | extern
    name: Optional[str] = None
    impl: Optional[Impl] = None
    trait_name: Optional[str] = None


class _Walker:
    def __init__(self, lx: LexResult, path: str):
        self.toks: List[Token] = lx.tokens
        self.n = len(self.toks)
        self.i = 0
        self.path = path
        self.idx = FileIndex(path=path)
        self.scopes: List[_Scope] = [_Scope("mod", None)]
        self.pending_cfg: Optional[str] = None
        self.pending_pub = False
        self.pending_export = False

    # -- token helpers ------------------------------------------------------

    def at(self, k: int = 0) -> Optional[Token]:
        j = self.i + k
        return self.toks[j] if 0 <= j < self.n else None

    def is_p(self, text: str, k: int = 0) -> bool:
        t = self.at(k)
        return t is not None and t.kind == "punct" and t.text == text

    def is_id(self, text: str, k: int = 0) -> bool:
        t = self.at(k)
        return t is not None and t.kind == "id" and t.text == text

    def module_path(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.scopes if s.kind == "mod" and s.name)

    def in_item_scope(self) -> bool:
        return self.scopes[-1].kind in _ITEM_SCOPES

    def take_meta(self):
        cfg, pub = self.pending_cfg, self.pending_pub
        self.pending_cfg, self.pending_pub = None, False
        return cfg, pub

    def container_name(self) -> Optional[str]:
        s = self.scopes[-1]
        if s.kind == "impl" and s.impl is not None:
            return s.impl.type_name
        if s.kind == "trait":
            return s.trait_name
        return None

    # -- balanced skips ------------------------------------------------------

    def skip_delims(self, open_t: str, close_t: str) -> None:
        """i sits on open_t; advance past its matching close."""
        depth = 0
        while self.i < self.n:
            if self.is_p(open_t):
                depth += 1
            elif self.is_p(close_t):
                depth -= 1
                if depth == 0:
                    self.i += 1
                    return
            self.i += 1

    def skip_generics(self) -> None:
        """i sits on '<'; skip the balanced angle region (treats every '<'
        as an opener — valid in declaration/type position)."""
        depth = 0
        while self.i < self.n:
            if self.is_p("<"):
                depth += 1
            elif self.is_p(">"):
                depth -= 1
                if depth == 0:
                    self.i += 1
                    return
            elif self.is_p("(") or self.is_p("[") or self.is_p("{"):
                self.skip_delims(self.at().text, {"(": ")", "[": "]", "{": "}"}[self.at().text])
                continue
            self.i += 1

    # -- main walk -----------------------------------------------------------

    def walk(self) -> FileIndex:
        while self.i < self.n:
            t = self.at()
            if t.kind == "punct":
                if t.text == "#":
                    self.attr()
                    continue
                if t.text == "{":
                    self.scopes.append(_Scope("body"))
                    self.i += 1
                    continue
                if t.text == "}":
                    if len(self.scopes) > 1:
                        self.scopes.pop()
                    self.i += 1
                    continue
                self.i += 1
                continue
            if t.kind != "id":
                self.i += 1
                continue

            if self.in_item_scope():
                kw = t.text
                if kw == "pub":
                    self.pending_pub = True
                    self.i += 1
                    if self.is_p("("):  # pub(crate) / pub(super)
                        self.skip_delims("(", ")")
                    continue
                if kw in ("unsafe", "async", "default"):
                    self.i += 1
                    continue
                if kw == "extern":
                    self.i += 1
                    if self.at() and self.at().kind == "str":
                        self.i += 1
                    if self.is_p("{"):  # foreign block
                        self.scopes.append(_Scope("extern"))
                        self.i += 1
                    continue  # `extern "C" fn` falls through to fn next loop
                if kw == "mod":
                    self.item_mod()
                    continue
                if kw == "fn":
                    self.item_fn()
                    continue
                if kw == "struct" or kw == "union":
                    self.item_struct(kw)
                    continue
                if kw == "enum":
                    self.item_enum()
                    continue
                if kw == "trait":
                    self.item_trait()
                    continue
                if kw == "impl":
                    self.item_impl()
                    continue
                if kw == "use":
                    self.item_use()
                    continue
                if kw in ("const", "static"):
                    self.item_const(kw)
                    continue
                if kw == "type":
                    self.item_type()
                    continue
                if kw == "macro_rules" and self.is_p("!", 1):
                    self.item_macro()
                    continue
                # anything else at item scope (let in const blocks, idents
                # in extern blocks, …): consume, maybe a call
                self.maybe_call()
                continue

            # body scope: collect call sites only
            self.maybe_call()
        return self.idx

    # -- attributes ----------------------------------------------------------

    def attr(self) -> None:
        # '#' ['!'] '[' … ']'
        self.i += 1
        if self.is_p("!"):
            self.i += 1
            if self.is_p("["):
                self.skip_delims("[", "]")
            return  # inner attribute: applies to the enclosing item, ignore
        if not self.is_p("["):
            return
        start = self.i
        depth = 0
        parts = []
        while self.i < self.n:
            t = self.at()
            if t.kind == "punct" and t.text == "[":
                depth += 1
            elif t.kind == "punct" and t.text == "]":
                depth -= 1
                if depth == 0:
                    self.i += 1
                    break
            if self.i > start or True:
                parts.append(t.text)
            self.i += 1
        text = " ".join(parts)
        if "cfg" in text.split("[ ")[0] or text.startswith("[ cfg"):
            self.pending_cfg = text
        if "macro_export" in text:
            self.pending_export = True
        # every other attribute (derive, allow, target_feature, test…): drop

    # -- items ----------------------------------------------------------------

    def item_mod(self) -> None:
        cfg, _pub = self.take_meta()
        self.i += 1  # 'mod'
        name_t = self.at()
        if name_t is None or name_t.kind != "id":
            return
        name = name_t.text
        self.i += 1
        if self.is_p(";"):
            self.idx.mods.append(
                ModDecl(name, name_t.line, cfg, inline=False, module=self.module_path())
            )
            self.i += 1
        elif self.is_p("{"):
            self.idx.mods.append(
                ModDecl(name, name_t.line, cfg, inline=True, module=self.module_path())
            )
            self.scopes.append(_Scope("mod", name))
            self.i += 1

    def item_fn(self) -> None:
        cfg, pub = self.take_meta()
        self.i += 1  # 'fn'
        name_t = self.at()
        if name_t is None or name_t.kind != "id":
            return
        name = name_t.text
        self.i += 1
        if self.is_p("<"):
            self.skip_generics()
        if not self.is_p("("):
            return
        arity, has_self = self.count_params()
        # Scan past return type / where clause to the body or ';'
        required = False
        while self.i < self.n:
            if self.is_p("{"):
                self.scopes.append(_Scope("body"))
                self.i += 1
                break
            if self.is_p(";"):
                required = True
                self.i += 1
                break
            if self.is_p("("):
                self.skip_delims("(", ")")
                continue
            if self.is_p("["):
                self.skip_delims("[", "]")
                continue
            if self.is_p("<"):
                self.skip_generics()
                continue
            self.i += 1

        scope = self.scopes[-2] if self.scopes[-1].kind == "body" else self.scopes[-1]
        container = None
        trait_of = None
        if scope.kind == "impl" and scope.impl is not None:
            container = scope.impl.type_name
            trait_of = scope.impl.trait_name
        elif scope.kind == "trait":
            container = scope.trait_name
        fn = Fn(
            name=name,
            arity=arity,
            has_self=has_self,
            line=name_t.line,
            is_pub=pub,
            cfg=cfg,
            module=self.module_path(),
            container=container,
            trait_of=trait_of,
            is_required_trait_method=required and scope.kind == "trait",
        )
        self.idx.fns.append(fn)
        if scope.kind == "impl" and scope.impl is not None:
            scope.impl.methods[name] = fn
        if scope.kind == "trait" and scope.trait_name in self.idx.traits:
            bucket = "required" if required else "provided"
            self.idx.traits[scope.trait_name][bucket][name] = fn

    def count_params(self) -> Tuple[int, bool]:
        """i sits on '('. Count top-level params; detect a self receiver."""
        first_toks: List[Token] = []
        depth = 0
        angle = 0
        count = 0
        saw_any = False
        at_param_start = True
        while self.i < self.n:
            t = self.at()
            if t.kind == "punct":
                if t.text in "([{":
                    depth += 1
                    self.i += 1
                    at_param_start = False
                    continue
                if t.text in ")]}":
                    depth -= 1
                    self.i += 1
                    if depth == 0 and t.text == ")":
                        break
                    continue
                if t.text == "<":
                    angle += 1
                elif t.text == ">":
                    angle = max(0, angle - 1)
                elif t.text == "," and depth == 1 and angle == 0:
                    count += 1
                    at_param_start = True
                    self.i += 1
                    continue
            if depth == 1 and t.kind in ("id", "life", "punct"):
                saw_any = True
                if at_param_start and len(first_toks) < 4:
                    first_toks.append(t)
            if depth >= 1 and at_param_start and len(first_toks) < 4 and count == 0:
                pass
            self.i += 1
        # trailing comma: `(a, b,)` — count counted it, but no param follows
        arity = count + 1 if saw_any else 0
        if saw_any and count > 0 and self._trailing_comma():
            arity -= 1
        has_self = any(t.kind == "id" and t.text == "self" for t in first_toks)
        return (arity - 1 if has_self else arity), has_self

    def _trailing_comma(self) -> bool:
        # look back: ... ',' ')'  (i is just past ')')
        j = self.i - 2
        t = self.toks[j] if 0 <= j < self.n else None
        return t is not None and t.kind == "punct" and t.text == ","

    def item_struct(self, kw: str) -> None:
        cfg, _pub = self.take_meta()
        self.i += 1
        name_t = self.at()
        if name_t is None or name_t.kind != "id":
            return
        name = name_t.text
        self.i += 1
        if self.is_p("<"):
            self.skip_generics()
        if self.is_id("where"):
            # `struct X<T> where T: Y { … }` — scan to the body or ';'
            while self.i < self.n and not (self.is_p("{") or self.is_p(";")):
                if self.is_p("<"):
                    self.skip_generics()
                    continue
                if self.is_p("("):
                    self.skip_delims("(", ")")
                    continue
                self.i += 1
        tuple_arity = None
        fields = None
        if self.is_p("("):
            tuple_arity = self.count_tuple_fields()
            # `struct X(…);`
            if self.is_p(";"):
                self.i += 1
        elif self.is_p("{"):
            fields = self.named_fields()
        elif self.is_p(";"):
            self.i += 1
        # `struct X where …;` / generics bound forms: best-effort
        self.idx.types.append(
            TypeItem(kw if kw == "union" else "struct", name, name_t.line, cfg,
                     self.module_path(), tuple_arity=tuple_arity, fields=fields)
        )

    def named_fields(self) -> List[str]:
        """i sits on the '{' of a named-field struct/union body: consume the
        balanced region and return the declared field names. cfg-gated
        fields are recorded unconditionally (more known names can only make
        the literal check more lenient)."""
        fields: List[str] = []
        depth = 0
        expecting = True
        while self.i < self.n:
            t = self.at()
            if t.kind == "punct":
                if t.text == "#" and depth == 1:
                    self.attr()
                    self.pending_cfg = None
                    continue
                if t.text == "<" and depth >= 1:
                    # field types only (structs carry no initializers), so
                    # every '<' here opens generics — commas inside stay
                    # invisible to the depth-1 separator logic
                    self.skip_generics()
                    continue
                if t.text in "([{":
                    depth += 1
                    self.i += 1
                    continue
                if t.text in ")]}":
                    depth -= 1
                    self.i += 1
                    if depth == 0:
                        break
                    continue
                if t.text == "," and depth == 1:
                    expecting = True
                    self.i += 1
                    continue
            if t.kind == "id" and depth == 1 and expecting:
                if t.text == "pub":
                    self.i += 1
                    if self.is_p("("):  # pub(crate) field
                        self.skip_delims("(", ")")
                    continue
                if self.is_p(":", 1):
                    fields.append(t.text)
                expecting = False
            self.i += 1
        return fields

    def count_tuple_fields(self) -> int:
        depth = 0
        angle = 0
        count = 0
        saw = False
        while self.i < self.n:
            t = self.at()
            if t.kind == "punct":
                if t.text in "([{":
                    depth += 1
                elif t.text in ")]}":
                    depth -= 1
                    if depth == 0:
                        self.i += 1
                        break
                elif t.text == "<":
                    angle += 1
                elif t.text == ">":
                    angle = max(0, angle - 1)
                elif t.text == "," and depth == 1 and angle == 0:
                    count += 1
                    self.i += 1
                    continue
            if depth == 1 and t.kind in ("id", "punct", "life"):
                saw = True
            self.i += 1
        n = count + 1 if saw else 0
        if saw and count > 0 and self._trailing_comma():
            n -= 1
        return n

    def item_enum(self) -> None:
        cfg, _pub = self.take_meta()
        self.i += 1
        name_t = self.at()
        if name_t is None or name_t.kind != "id":
            return
        name = name_t.text
        self.i += 1
        if self.is_p("<"):
            self.skip_generics()
        variants = {}
        if self.is_p("{"):
            depth = 0
            expecting = True
            while self.i < self.n:
                t = self.at()
                if t.kind == "punct":
                    if t.text == "{":
                        depth += 1
                        self.i += 1
                        continue
                    if t.text == "}":
                        depth -= 1
                        self.i += 1
                        if depth == 0:
                            break
                        continue
                    if t.text == "," and depth == 1:
                        expecting = True
                        self.i += 1
                        continue
                    if t.text == "#" and depth == 1:
                        self.attr()
                        self.pending_cfg = None
                        continue
                    if t.text == "(" and depth == 1:
                        # tuple variant payload
                        last = list(variants)[-1] if variants else None
                        ar = self.count_tuple_fields()
                        if last is not None:
                            variants[last] = ar
                        continue
                    if t.text == "=" and depth == 1:
                        # discriminant expr: skip to ',' or '}'
                        self.i += 1
                        while self.i < self.n and not (
                            self.is_p(",") or self.is_p("}")
                        ):
                            self.i += 1
                        continue
                if t.kind == "id" and depth == 1 and expecting:
                    variants[t.text] = None
                    expecting = False
                self.i += 1
        self.idx.types.append(
            TypeItem("enum", name, name_t.line, cfg, self.module_path(),
                     variants=variants)
        )

    def item_trait(self) -> None:
        cfg, _pub = self.take_meta()
        self.i += 1
        name_t = self.at()
        if name_t is None or name_t.kind != "id":
            return
        name = name_t.text
        self.i += 1
        # skip generics and supertrait bounds to the '{'
        while self.i < self.n and not self.is_p("{"):
            if self.is_p("<"):
                self.skip_generics()
                continue
            if self.is_p("("):
                self.skip_delims("(", ")")
                continue
            if self.is_p(";"):  # `trait Alias = …;`
                self.i += 1
                return
            self.i += 1
        self.idx.types.append(
            TypeItem("trait", name, name_t.line, cfg, self.module_path())
        )
        self.idx.traits[name] = {"required": {}, "provided": {}}
        self.scopes.append(_Scope("trait", name, trait_name=name))
        self.i += 1  # '{'

    def item_impl(self) -> None:
        cfg, _pub = self.take_meta()
        line = self.at().line
        self.i += 1
        if self.is_p("<"):
            self.skip_generics()
        # Collect path A (maybe `Trait for Type`); stop at '{' or 'for'
        first: List[str] = []
        second: List[str] = []
        cur = first
        while self.i < self.n and not self.is_p("{"):
            t = self.at()
            if t.kind == "id" and t.text == "for":
                cur = second
                self.i += 1
                continue
            if t.kind == "id" and t.text == "where":
                # where clause: skip to '{'
                while self.i < self.n and not self.is_p("{"):
                    if self.is_p("<"):
                        self.skip_generics()
                        continue
                    if self.is_p("("):
                        self.skip_delims("(", ")")
                        continue
                    self.i += 1
                break
            if t.kind == "id" and t.text not in ("dyn", "mut", "const"):
                cur.append(t.text)
            if self.is_p("<"):
                self.skip_generics()
                continue
            if self.is_p("("):
                self.skip_delims("(", ")")
                continue
            self.i += 1
        if not self.is_p("{"):
            return
        if second:
            trait_name = first[-1] if first else None
            type_name = second[-1]
        else:
            trait_name = None
            type_name = first[-1] if first else "?"
        imp = Impl(type_name, trait_name, line, cfg, self.module_path())
        self.idx.impls.append(imp)
        self.scopes.append(_Scope("impl", type_name, impl=imp))
        self.i += 1  # '{'

    def item_use(self) -> None:
        cfg, pub = self.take_meta()
        del cfg
        line = self.at().line
        self.i += 1
        prefix: List[str] = []
        self._use_tree(prefix, pub, line)
        if self.is_p(";"):
            self.i += 1

    def _use_tree(self, prefix: List[str], pub: bool, line: int) -> None:
        segs: List[str] = list(prefix)
        while self.i < self.n:
            t = self.at()
            if t is None:
                return
            if t.kind == "id":
                nxt = self.at(1)
                if nxt is not None and nxt.kind == "punct" and nxt.text == "::":
                    segs.append(t.text)
                    self.i += 2
                    continue
                # leaf, maybe with alias
                leaf = t.text
                self.i += 1
                alias = None
                if self.is_id("as"):
                    self.i += 1
                    a = self.at()
                    if a is not None and a.kind == "id":
                        alias = a.text
                        self.i += 1
                self.idx.uses.append(
                    Use(tuple(segs + [leaf]), alias, pub, line, self.module_path())
                )
                return
            if t.kind == "punct" and t.text == "*":
                self.i += 1
                self.idx.uses.append(
                    Use(tuple(segs + ["*"]), None, pub, line, self.module_path())
                )
                return
            if t.kind == "punct" and t.text == "{":
                self.i += 1
                while self.i < self.n and not self.is_p("}"):
                    self._use_tree(segs, pub, line)
                    if self.is_p(","):
                        self.i += 1
                if self.is_p("}"):
                    self.i += 1
                return
            return

    def item_const(self, kw: str) -> None:
        cfg, _pub = self.take_meta()
        self.i += 1
        if self.is_id("fn"):  # `const fn`
            self.item_fn()
            return
        if self.is_id("mut"):  # `static mut`
            self.i += 1
        name_t = self.at()
        if name_t is None or name_t.kind != "id":
            return
        if name_t.text == "_":  # `const _: () = …;`
            pass
        self.idx.values.append(
            ValueItem(kw, name_t.text, name_t.line, cfg, self.module_path(),
                      container=self.container_name())
        )
        self.i += 1
        # skip `: Type = expr;` with balanced nesting (initializer may hold
        # braces — e.g. `static K: Kernels = Kernels { … };`), collecting
        # call sites inside the initializer expression.
        depth = 0
        while self.i < self.n:
            if self.is_p("(") or self.is_p("[") or self.is_p("{"):
                depth += 1
                self.i += 1
                continue
            if self.is_p(")") or self.is_p("]") or self.is_p("}"):
                depth -= 1
                self.i += 1
                continue
            if depth == 0 and self.is_p(";"):
                self.i += 1
                return
            if self.at().kind == "id":
                self.maybe_call()
                continue
            self.i += 1

    def item_type(self) -> None:
        cfg, _pub = self.take_meta()
        self.i += 1
        name_t = self.at()
        if name_t is None or name_t.kind != "id":
            return
        self.idx.types.append(
            TypeItem("type", name_t.text, name_t.line, cfg, self.module_path())
        )
        self.i += 1
        depth = 0
        while self.i < self.n:
            if self.is_p("<"):
                self.skip_generics()
                continue
            if self.is_p("(") or self.is_p("["):
                depth += 1
            elif self.is_p(")") or self.is_p("]"):
                depth -= 1
            elif depth == 0 and self.is_p(";"):
                self.i += 1
                return
            self.i += 1

    def item_macro(self) -> None:
        cfg, _pub = self.take_meta()
        # 'macro_rules' '!' name '{' … '}'
        self.i += 2
        name_t = self.at()
        if name_t is None or name_t.kind != "id":
            return
        exported = self.pending_export
        self.pending_export = False
        self.idx.values.append(
            ValueItem("macro", name_t.text, name_t.line, cfg, self.module_path(),
                      exported=exported)
        )
        self.i += 1
        if self.is_p("{"):
            self.skip_delims("{", "}")
        elif self.is_p("("):
            self.skip_delims("(", ")")

    # -- call sites -----------------------------------------------------------

    def maybe_call(self) -> None:
        """At an ident (any scope): if it heads `path::to::name(…)`, record a
        call site with its argument count; otherwise just step over it."""
        t = self.at()
        if t is None or t.kind != "id":
            self.i += 1
            return
        prev = self.toks[self.i - 1] if self.i > 0 else None
        # method call / definition / macro name / field access: not a free call
        if prev is not None and prev.kind == "punct" and prev.text in (".", "'"):
            self._skip_path()
            return
        if prev is not None and prev.kind == "id" and prev.text in ("fn", "mod", "struct", "enum", "trait", "impl", "use", "as"):
            self.i += 1
            return
        # `let Path::To::X …` heads a pattern: tuple patterns (`let Foo(..)`)
        # mimic call syntax with arbitrary sub-patterns, so never record a
        # Call — but the struct-pattern brace form below still spells field
        # names with the same existence obligation as a literal.
        in_pattern = prev is not None and prev.kind == "id" and prev.text == "let"
        segs = [t.text]
        j = self.i + 1
        while (
            j + 1 < self.n
            and self.toks[j].kind == "punct"
            and self.toks[j].text == "::"
            and self.toks[j + 1].kind == "id"
        ):
            segs.append(self.toks[j + 1].text)
            j += 2
        # turbofish: name::<T>(…)
        if (
            j + 1 < self.n
            and self.toks[j].kind == "punct"
            and self.toks[j].text == "::"
            and self.toks[j + 1].kind == "punct"
            and self.toks[j + 1].text == "<"
        ):
            self.i = j + 1
            self.skip_generics()
            j = self.i
        if j < self.n and self.toks[j].kind == "punct" and self.toks[j].text == "!":
            # macro invocation: skip its delimited body entirely
            self.i = j + 1
            if self.i < self.n and self.at().kind == "punct" and self.at().text in "([{":
                o = self.at().text
                self.skip_delims(o, {"(": ")", "[": "]", "{": "}"}[o])
            return
        if j < self.n and self.toks[j].kind == "punct" and self.toks[j].text == "(":
            if in_pattern:
                self.i = j
                return
            line = t.line
            module = self.module_path()
            in_body = self.scopes[-1].kind == "body"
            self.i = j
            arity = self.count_args()
            self.idx.calls.append(Call(tuple(segs), arity, line, module, in_body))
            return
        # struct literal / struct pattern: `Path::Type { field, field: v, .. }`.
        # Only Type-cased heads are candidates; _peek_struct_lit rejects brace
        # regions whose content parses as a block rather than a field list
        # (e.g. the body of `if x == E::V { … }`). The braces are deliberately
        # NOT consumed: walk() re-enters them as a body scope so nested
        # literals and calls in the field values still get collected.
        if (
            j < self.n
            and self.toks[j].kind == "punct"
            and self.toks[j].text == "{"
            and segs[-1][:1].isupper()
        ):
            fields = self._peek_struct_lit(j)
            if fields is not None:
                self.idx.lits.append(
                    StructLit(tuple(segs), fields, t.line, self.module_path())
                )
            self.i = j
            return
        self.i = j

    def _peek_struct_lit(self, j: int) -> Optional[List[str]]:
        """Non-consuming look at the brace region starting at toks[j] ('{'):
        return the field names it spells if it reads as a struct-literal /
        struct-pattern field list, else None. Leniency rules from the module
        docstring apply: anything ambiguous returns None (the region is then
        treated as a plain block and never checked)."""
        fields: List[str] = []
        depth = 0
        expecting = True
        k = j
        while k < self.n:
            t = self.toks[k]
            if t.kind == "punct":
                if t.text in "([{":
                    if depth == 0:
                        depth = 1
                        k += 1
                        continue
                    if expecting:
                        # a delimited region where a field name belongs:
                        # `{ (a, b) = f(); … }` is a block, not a literal
                        return None
                    depth += 1
                    k += 1
                    continue
                if t.text in ")]}":
                    depth -= 1
                    if depth == 0:
                        return fields if t.text == "}" else None
                    k += 1
                    continue
                if depth == 1:
                    if t.text == ",":
                        expecting = True
                        k += 1
                        continue
                    if t.text == ";":
                        # statement separator: definitely a block
                        return None
                    if t.text in ("..", "..=") and expecting:
                        # rest pattern / functional-record-update tail: valid
                        # literal/pattern; remaining tokens are a base expr
                        d2 = 1
                        k += 1
                        while k < self.n:
                            t2 = self.toks[k]
                            if t2.kind == "punct" and t2.text in "([{":
                                d2 += 1
                            elif t2.kind == "punct" and t2.text in ")]}":
                                d2 -= 1
                                if d2 == 0:
                                    return fields if t2.text == "}" else None
                            k += 1
                        return None
                    if expecting and t.text != "..":
                        # `#[attr]`, `=>`, operators… where a field belongs
                        return None
                elif depth > 1 and expecting:
                    expecting = False
                k += 1
                continue
            if depth == 1 and expecting:
                if t.kind == "id":
                    if t.text in ("ref", "mut", "box"):
                        k += 1
                        continue
                    nxt = self.toks[k + 1] if k + 1 < self.n else None
                    if nxt is not None and nxt.kind == "punct" and nxt.text == ":":
                        fields.append(t.text)
                        k += 2
                        expecting = False
                        continue
                    if nxt is not None and nxt.kind == "punct" and nxt.text in (",", "}"):
                        # shorthand: `Foo { x, y }` / pattern binding
                        fields.append(t.text)
                        k += 1
                        expecting = False
                        continue
                    # `ident (`, `ident =>`, `let ident`…: block content
                    return None
                if t.kind == "num":
                    nxt = self.toks[k + 1] if k + 1 < self.n else None
                    if nxt is not None and nxt.kind == "punct" and nxt.text == ":":
                        # brace-init of a tuple struct by index — legal but
                        # positional; nothing nameable to check
                        k += 2
                        expecting = False
                        continue
                    return None
                # string/char/lifetime where a field name belongs
                return None
            if depth == 1 and not expecting and t.kind == "id":
                k += 1
                continue
            k += 1
        return None

    def _skip_path(self) -> None:
        self.i += 1
        while (
            self.i + 1 < self.n
            and self.is_p("::")
            and self.toks[self.i + 1].kind == "id"
        ):
            self.i += 2

    def count_args(self) -> Optional[int]:
        """i sits on the call's '('. Count top-level commas; None if a
        top-level '|' (closure) or '<' makes counting unreliable."""
        depth = 0
        count = 0
        saw = False
        unreliable = False
        while self.i < self.n:
            t = self.at()
            if t.kind == "punct":
                if t.text in "([{":
                    depth += 1
                    self.i += 1
                    continue
                if t.text in ")]}":
                    depth -= 1
                    self.i += 1
                    if depth == 0 and t.text == ")":
                        break
                    continue
                if depth == 1 and t.text in ("|", "<", ">"):
                    unreliable = True
                if depth == 1 and t.text == ",":
                    count += 1
                    self.i += 1
                    continue
            if depth >= 1:
                saw = saw or t.kind in ("id", "num", "str", "char", "life") or (
                    t.kind == "punct" and t.text not in ","
                )
            if depth == 1 and t.kind == "id":
                # nested calls inside arguments still matter
                save = self.i
                self.maybe_call()
                if self.i == save:
                    self.i += 1
                continue
            self.i += 1
        if unreliable:
            return None
        n = count + 1 if saw else 0
        if saw and count > 0 and self._trailing_comma():
            n -= 1
        return n


def index_file(lx: LexResult, path: str) -> FileIndex:
    return _Walker(lx, path).walk()
