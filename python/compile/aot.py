"""AOT pipeline: lower every experiment config to HLO text artifacts.

For each config ``name`` this emits ``artifacts/<name>/``:

  ``manifest.json``     param layout (sorted keys), input/output specs,
                        hyperparameters, FLOP accounting
  ``init.hlo.txt``      seed:i32[] → (params…)
  ``train_step.hlo.txt``(params…, m…, v…, step:f32[], batch…) →
                        (params…, m…, v…, loss)   [unless forward_only]
  ``forward.hlo.txt``   (params…, tokens|images) → logits
  ``filters.hlo.txt``   (params…) → h[N,D,L] of block 0   [hyena mixers]

Interchange format is HLO **text**: jax ≥ 0.5 serialized protos carry 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md). Incremental: a config is skipped when
its manifest exists and records the same config dict, unless --force.

Usage: ``python -m compile.aot [--out DIR] [--only GLOB] [--list] [--force]``
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import filters as filters_mod
from . import model, ops, train
from .configs import CONFIGS


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flat_keys(params: dict) -> list[str]:
    return sorted(params.keys())


def flatten(params: dict) -> list:
    return [params[k] for k in flat_keys(params)]


def unflatten(keys: list[str], vals) -> dict:
    return dict(zip(keys, vals))


def _spec(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def _spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(jnp.dtype(s.dtype).name)}


def build_artifacts(name: str, cfg: dict, outdir: str, force: bool) -> bool:
    adir = os.path.join(outdir, name)
    man_path = os.path.join(adir, "manifest.json")
    if not force and os.path.exists(man_path):
        try:
            with open(man_path) as f:
                if json.load(f).get("config") == cfg:
                    return False  # up to date
        except Exception:
            pass
    os.makedirs(adir, exist_ok=True)
    t0 = time.time()

    family = cfg["family"]
    init_fn = model.init_lm if family == "lm" else model.init_img
    fwd_fn = model.forward_lm if family == "lm" else model.forward_img

    # Shapes are driven by a concrete (abstract-eval'd) init.
    params0 = jax.eval_shape(lambda s: init_fn(s, cfg), jnp.zeros((), jnp.int32))
    keys = flat_keys(params0)
    pspecs = [_spec(params0[k]) for k in keys]
    B, L = cfg["batch"], cfg["seqlen"]

    # ---- init: seed → (params…) --------------------------------------------
    def init_flat(seed):
        return tuple(flatten(init_fn(seed, cfg)))

    lowered = jax.jit(init_flat).lower(jax.ShapeDtypeStruct((), jnp.int32))
    with open(os.path.join(adir, "init.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    # ---- forward ------------------------------------------------------------
    if family == "lm":
        data_specs = [jax.ShapeDtypeStruct((B, L), jnp.int32)]
    else:
        img = cfg["image"]
        data_specs = [jax.ShapeDtypeStruct((B, img, img), jnp.float32)]

    def fwd_flat(*args):
        p = unflatten(keys, args[: len(keys)])
        return (fwd_fn(p, args[len(keys)], cfg),)

    lowered = jax.jit(fwd_flat).lower(*pspecs, *data_specs)
    with open(os.path.join(adir, "forward.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    # ---- train_step ----------------------------------------------------------
    train_inputs = []
    if not cfg.get("forward_only", False):
        if family == "lm":
            step_fn = train.make_lm_train_step(cfg)
            batch_specs = [
                jax.ShapeDtypeStruct((B, L), jnp.int32),   # tokens
                jax.ShapeDtypeStruct((B, L), jnp.int32),   # targets
                jax.ShapeDtypeStruct((B, L), jnp.float32), # loss mask
            ]
            train_inputs = ["tokens", "targets", "mask"]
        else:
            step_fn = train.make_img_train_step(cfg)
            img = cfg["image"]
            batch_specs = [
                jax.ShapeDtypeStruct((B, img, img), jnp.float32),
                jax.ShapeDtypeStruct((B,), jnp.int32),
            ]
            train_inputs = ["images", "labels"]

        nk = len(keys)

        def step_flat(*args):
            p = unflatten(keys, args[:nk])
            m = unflatten(keys, args[nk : 2 * nk])
            v = unflatten(keys, args[2 * nk : 3 * nk])
            step = args[3 * nk]
            batch = args[3 * nk + 1 :]
            new_p, new_m, new_v, loss = step_fn(p, m, v, step, *batch)
            return tuple(flatten(new_p)) + tuple(flatten(new_m)) + tuple(
                flatten(new_v)
            ) + (loss,)

        # Donate params/m/v: input/output aliasing lets XLA update the
        # optimizer state in place instead of allocating + copying every
        # tensor each step (§Perf L2 lever; measured in EXPERIMENTS.md).
        donate = tuple(range(3 * nk))
        lowered = jax.jit(step_flat, donate_argnums=donate).lower(
            *pspecs, *pspecs, *pspecs,
            jax.ShapeDtypeStruct((), jnp.float32),
            *batch_specs,
        )
        with open(os.path.join(adir, "train_step.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))

        # Non-donated variant kept for the §Perf ablation.
        if cfg.get("emit_undonated", False):
            lowered = jax.jit(step_flat).lower(
                *pspecs, *pspecs, *pspecs,
                jax.ShapeDtypeStruct((), jnp.float32),
                *batch_specs,
            )
            with open(os.path.join(adir, "train_step_nodonate.hlo.txt"), "w") as f:
                f.write(to_hlo_text(lowered))

    # ---- filters dump (hyena mixers): Fig D.5 driver -------------------------
    # Lowered over ONLY the block-0 filter params (jit would DCE the rest
    # anyway, changing the artifact's true arity); the manifest records which
    # param names feed it, in flattening order.
    has_filters = cfg.get("mixer") == "hyena"
    filter_param_names = []
    if has_filters:
        N, D = cfg.get("order", 2), cfg["width"]
        prefix = "blocks.0.mixer.filter."
        filter_param_names = [k for k in keys if k.startswith(prefix)]
        fspecs = [pspecs[keys.index(k)] for k in filter_param_names]

        def filt_flat(*args):
            fsub = {
                k[len(prefix):]: v for k, v in zip(filter_param_names, args)
            }
            h = filters_mod.materialize_filter(
                fsub, cfg.get("filter_kind", "implicit"), N, D, L, cfg
            )
            return (h,)

        lowered = jax.jit(filt_flat).lower(*fspecs)
        with open(os.path.join(adir, "filters.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))

    # ---- manifest -------------------------------------------------------------
    manifest = {
        "name": name,
        "config": cfg,
        "params": [dict(name=k, **_spec_json(s)) for k, s in zip(keys, pspecs)],
        "data_inputs": {
            "forward": [_spec_json(s) for s in data_specs],
            "train": train_inputs,
        },
        "param_count": int(sum(int(jnp.prod(jnp.array(s.shape))) for s in pspecs)),
        "flops_per_token": model.flops_per_token_lm(cfg) if family == "lm" else None,
        "flops_per_step": model.flops_per_step(cfg, B) if family == "lm" else None,
        "has_train_step": not cfg.get("forward_only", False),
        "has_filters": has_filters,
        "filter_params": filter_param_names,
    }
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)

    # ---- goldens for the rust integration test -------------------------------
    if name == "golden_tiny":
        import numpy as np

        p = init_fn(0, cfg)
        rng = np.random.RandomState(0)
        toks = rng.randint(0, cfg["vocab"], size=(B, L)).astype(np.int32)
        logits = np.asarray(fwd_fn(p, jnp.asarray(toks), cfg))
        golden = {
            "tokens": toks.flatten().tolist(),
            "logits_head": logits.flatten()[:64].tolist(),
            "logits_sum": float(logits.sum()),
            "logits_shape": list(logits.shape),
        }
        with open(os.path.join(adir, "golden.json"), "w") as f:
            json.dump(golden, f)

    dt = time.time() - t0
    print(f"  {name}: {len(keys)} params, {dt:.1f}s", flush=True)
    return True


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="glob over config names")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    names = sorted(CONFIGS)
    if args.only:
        names = [n for n in names if fnmatch.fnmatch(n, args.only)]
    if args.list:
        for n in names:
            print(n)
        return
    print(f"lowering {len(names)} configs -> {args.out}", flush=True)
    built = 0
    for n in names:
        built += build_artifacts(n, CONFIGS[n], args.out, args.force)
    print(f"done: {built} built, {len(names) - built} up-to-date")


if __name__ == "__main__":
    main()
