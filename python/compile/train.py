"""Training step: AdamW + linear-warmup/cosine-decay LR, all inside the graph.

The step counter enters as a traced f32 scalar so the LR schedule (paper
App. A.2, Tab. A.1/A.3) is computed inside XLA — the Rust trainer only
increments an integer. Matches the paper's recipe: AdamW β=(0.9, 0.98),
weight decay 0.1, linear warmup → cosine decay to lr_min.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model


def lr_schedule(step, cfg):
    peak = cfg.get("lr", 6e-4)
    warm = float(cfg.get("warmup_steps", 100))
    total = float(cfg.get("total_steps", 1000))
    lr_min = cfg.get("lr_min", peak * 0.1)
    warm_lr = peak * (step + 1.0) / warm
    prog = jnp.clip((step - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
    cos_lr = lr_min + 0.5 * (peak - lr_min) * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warm, warm_lr, cos_lr)


def _decay_mask(name: str, arr) -> bool:
    """Weight decay on matrices only (not biases/LN/embedding-like vectors)."""
    return arr.ndim >= 2


def adamw_step(params: dict, grads: dict, m: dict, v: dict, step, cfg):
    b1 = cfg.get("beta1", 0.9)
    b2 = cfg.get("beta2", 0.98)
    eps = cfg.get("adam_eps", 1e-8)
    wd = cfg.get("weight_decay", 0.1)
    lr = lr_schedule(step, cfg)
    t = step + 1.0
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        m_k = b1 * m[k] + (1.0 - b1) * g
        v_k = b2 * v[k] + (1.0 - b2) * g * g
        upd = (m_k / bc1) / (jnp.sqrt(v_k / bc2) + eps)
        if _decay_mask(k, params[k]):
            upd = upd + wd * params[k]
        new_p[k] = params[k] - lr * upd
        new_m[k] = m_k
        new_v[k] = v_k
    return new_p, new_m, new_v


def make_lm_train_step(cfg):
    """(params, m, v, step, tokens, targets, mask) → (params', m', v', loss)."""

    def step_fn(params, m, v, step, tokens, targets, mask):
        loss, grads = jax.value_and_grad(model.lm_loss)(
            params, tokens, targets, mask, cfg
        )
        # Gradient clipping by global norm (standard GPT recipe).
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        clip = cfg.get("grad_clip", 1.0)
        scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
        grads = {k: g * scale for k, g in grads.items()}
        new_p, new_m, new_v = adamw_step(params, grads, m, v, step, cfg)
        return new_p, new_m, new_v, loss

    return step_fn


def make_img_train_step(cfg):
    """(params, m, v, step, images, labels) → (params', m', v', loss)."""

    def step_fn(params, m, v, step, images, labels):
        loss, grads = jax.value_and_grad(model.img_loss)(params, images, labels, cfg)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        clip = cfg.get("grad_clip", 1.0)
        scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
        grads = {k: g * scale for k, g in grads.items()}
        new_p, new_m, new_v = adamw_step(params, grads, m, v, step, cfg)
        return new_p, new_m, new_v, loss

    return step_fn
