"""Experiment configs — one entry per AOT artifact (DESIGN.md §5 index).

Naming scheme:
  ``ar_<filter>_L<len>``   E1  Fig 4.1 / Tab A.2 — conv parametrizations
  ``op_<kind>_L<len>``     E2  Tab 4.2 — operator comparison
  ``lm_<kind>_wt``         E3  Tab 4.3 — WikiText-style LM shootout
  ``lm_<kind>_<size>``     E4  Tab 4.4 / Fig 4.2 — scaling on TinyPile
  ``rt_<kind>_L<len>``     E6  Fig 4.3 — runtime benches (forward only)
  ``img_<kind>``           E7  Tab 4.7 — image classification
  ``arith_d<depth>``       E9  Fig C.1 — learning arithmetic
  ``abl_*``                ablations (Sec. 3.3 design choices)
  ``golden_tiny``          rust↔python integration golden

Scale substitutions vs the paper are catalogued in DESIGN.md §3: the tiny
widths/lengths here are the CPU-testbed equivalents of the paper's A100
settings; relative comparisons (who wins, crossovers) are what we reproduce.
"""
from __future__ import annotations

# Synthetic-task defaults (paper Tab. A.1: 2 layers, width 64, AdamW).
_SYN = dict(
    family="lm",
    depth=2,
    width=64,
    mlp_ratio=2.0,
    vocab=64,          # embedding slots; effective vocab varied in data
    batch=16,
    order=2,
    n_heads=2,
    short_filter=3,
    pe_features=8,
    filter_width=32,
    filter_depth=4,
    sine_freq=14.0,
    lr=5e-4,
    warmup_steps=100,
    total_steps=2000,
    weight_decay=0.1,
)

# TinyPile LM defaults (paper Tab. A.3/A.4 scaled down).
_LM = dict(
    family="lm",
    mlp_ratio=4.0,
    vocab=96,          # char tokenizer
    batch=8,
    seqlen=256,
    n_heads=4,
    order=2,
    short_filter=3,
    pe_features=8,
    filter_width=64,
    filter_depth=4,
    sine_freq=14.0,
    lr=6e-4,
    warmup_steps=100,
    total_steps=2000,
    weight_decay=0.1,
)


def _syn(mixer, seqlen, **kw):
    c = dict(_SYN, mixer=mixer, seqlen=seqlen)
    c.update(kw)
    return c


def _lm(mixer, depth, width, **kw):
    c = dict(_LM, mixer=mixer, depth=depth, width=width)
    c.update(kw)
    return c


CONFIGS: dict[str, dict] = {}

# --- E1: long-convolution parametrizations (Fig 4.1 / Tab A.2) -------------
for fk in ["implicit", "ckconv", "conv1d", "fno", "ssm", "tf"]:
    for L in [128, 512]:
        CONFIGS[f"ar_{fk}_L{L}"] = _syn("hyena", L, filter_kind=fk)

# --- E2: operator comparison (Tab 4.2) --------------------------------------
for kind in ["hyena", "attn", "flash", "gss", "h3", "aft", "rwkv"]:
    CONFIGS[f"op_{kind}_L1024"] = _syn(kind, 1024, filter_kind="implicit", batch=8)

# --- E3: WikiText-style LM shootout (Tab 4.3) --------------------------------
CONFIGS["lm_attn_wt"] = _lm("attn", 4, 128)
CONFIGS["lm_hyena3_wt"] = _lm("hyena", 4, 128, order=3, filter_kind="implicit")
CONFIGS["lm_hyena3slim_wt"] = _lm(
    "hyena", 6, 128, order=3, filter_kind="implicit", mlp_ratio=2.0
)
CONFIGS["lm_aft_wt"] = _lm("aft", 4, 128)
CONFIGS["lm_rwkv_wt"] = _lm("rwkv", 4, 128)

# --- E4: TinyPile scaling (Tab 4.4 / Fig 4.2) --------------------------------
CONFIGS["lm_gpt_s"] = _lm("attn", 4, 128)
CONFIGS["lm_hyena_s"] = _lm("hyena", 4, 128, filter_kind="implicit", emit_undonated=True)
CONFIGS["lm_gpt_m"] = _lm("attn", 6, 192, batch=8)
CONFIGS["lm_hyena_m"] = _lm("hyena", 6, 192, filter_kind="implicit", batch=8)
# E4 models double as the end-to-end pretrain driver targets.

# --- E6: runtime benches (Fig 4.3; forward-only artifacts) -------------------
for kind in ["hyena", "attn", "flash"]:
    for L in [256, 512, 1024, 2048, 4096, 8192]:
        if kind == "attn" and L > 4096:
            continue  # exact attention: L² memory blow-up, paper marks ✗
        CONFIGS[f"rt_{kind}_L{L}"] = _syn(
            kind, L, filter_kind="implicit", batch=4, depth=1, forward_only=True
        )
# Pallas-kernel variant of the Hyena forward (DFT-matmul hot path).
for L in [256, 1024]:
    CONFIGS[f"rt_hyenapallas_L{L}"] = _syn(
        "hyena", L, filter_kind="implicit", batch=4, depth=1,
        forward_only=True, use_pallas=True,
    )

# --- E7: image classification (Tab 4.7) --------------------------------------
_IMG = dict(
    family="img",
    depth=4,
    width=96,
    mlp_ratio=2.0,
    patch=4,
    image=32,
    channels=1,
    classes=10,
    seqlen=64,          # (32/4)² patches
    batch=16,
    n_heads=2,
    order=2,
    short_filter=3,
    pe_features=8,
    filter_width=32,
    filter_depth=4,
    sine_freq=14.0,
    lr=5e-4,
    warmup_steps=100,
    total_steps=2000,
    weight_decay=0.05,
    vocab=0,
)
CONFIGS["img_vit"] = dict(_IMG, mixer="attn")
CONFIGS["img_hyena"] = dict(_IMG, mixer="hyena", filter_kind="implicit")

# --- E9: learning arithmetic (Fig C.1) ---------------------------------------
for d in [1, 2, 3]:
    CONFIGS[f"arith_d{d}"] = _syn(
        "hyena", 32, filter_kind="implicit", depth=d, vocab=16, batch=32
    )

# --- ablations (Sec. 3.3 / App. D design choices) ----------------------------
CONFIGS["abl_sine1"] = _syn("hyena", 512, filter_kind="implicit", sine_freq=1.0)
CONFIGS["abl_sine10"] = _syn("hyena", 512, filter_kind="implicit", sine_freq=10.0)
CONFIGS["abl_order1"] = _syn("hyena", 512, filter_kind="implicit", order=1)
CONFIGS["abl_order3"] = _syn("hyena", 512, filter_kind="implicit", order=3)
CONFIGS["abl_noshort"] = _syn("hyena", 512, filter_kind="implicit", short_filter=0)
CONFIGS["abl_pe32"] = _syn("hyena", 512, filter_kind="implicit", pe_features=32)

# --- golden: rust↔python numerical integration -------------------------------
CONFIGS["golden_tiny"] = _syn(
    "hyena", 16, filter_kind="implicit", depth=1, width=32, vocab=32, batch=2
)
