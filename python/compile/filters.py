"""Long-convolution filter parametrizations (paper Sec. 3.3 + App. A.1).

Six interchangeable schemes, matching the comparison of Fig. 4.1 / Tab. A.2:

====================  =========================================================
``implicit`` (Hyena)  sine-activated FFN over a complex-exponential positional
                      encoding, modulated by an exponential-decay window
                      (Eq. 7, Fig. 3.1, App. D.3)
``ckconv``            same FFN, no decay window (Romero et al., 2021b)
``conv1d``            explicit FIR taps, fixed filter size M (CNN baseline)
``fno``               explicit frequency-domain modes (Li et al., 2020)
``ssm``               diagonal state-space model à la S4D (Gu et al., 2021)
``tf``                transfer function: ratio of polynomials evaluated on the
                      unit circle (classical generalization of SSMs)
====================  =========================================================

Every scheme exposes:
  ``init_<kind>(key, N, D, cfg) -> params-subtree (dict of arrays)``
  ``materialize_<kind>(params, N, D, L, cfg) -> h  # (N, D, L) float32``

The per-channel skip bias (the ``D δ_t`` term) is owned by the operator, not
the filter, so all schemes compete on the long-range component only.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Positional encoding (App. D.3): truncated complex-exponential basis.
# ---------------------------------------------------------------------------


def positional_encoding(L: int, K: int) -> jnp.ndarray:
    """``t ↦ [t_norm, Re ρ_0..ρ_{K-1}, Im ρ_0..ρ_{K-1}]`` with ρ_k = e^{i2πkt/L}.

    Returns ``(L, 2K+1)``. The feature count 2K+1 preconditions the filter
    spectrum at init (App. D.3): filters resemble low-pass filters with
    cut-off ≈ 2K+1, compensated by the sine-activation frequency ω.
    """
    t = jnp.arange(L, dtype=jnp.float32)
    tn = t / max(L - 1, 1)
    k = jnp.arange(K, dtype=jnp.float32)
    ang = 2.0 * math.pi * k[None, :] * t[:, None] / L
    return jnp.concatenate([tn[:, None], jnp.cos(ang), jnp.sin(ang)], axis=-1)


# ---------------------------------------------------------------------------
# implicit (Hyena) & ckconv: sine-FFN over the positional encoding.
# ---------------------------------------------------------------------------


def _ffn_sizes(cfg):
    K = cfg.get("pe_features", 8)
    width = cfg.get("filter_width", 32)
    depth = cfg.get("filter_depth", 4)
    return 2 * K + 1, width, depth


def init_ffn_filter(key, N: int, D: int, cfg) -> dict:
    """Shared init for ``implicit`` and ``ckconv``."""
    d_in, width, depth = _ffn_sizes(cfg)
    sizes = [d_in] + [width] * (depth - 1) + [N * D]
    p = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, k1, k2 = jax.random.split(key, 3)
        bound = 1.0 / math.sqrt(a)
        p[f"w{i}"] = jax.random.uniform(k1, (a, b), minval=-bound, maxval=bound)
        p[f"b{i}"] = jax.random.uniform(k2, (b,), minval=-bound, maxval=bound)
    return p


def _ffn_eval(params, N: int, D: int, L: int, cfg) -> jnp.ndarray:
    """Run the sine-activated FFN across all L positions: ``(N, D, L)``."""
    _, _, depth = _ffn_sizes(cfg)
    omega = cfg.get("sine_freq", 14.0)
    z = positional_encoding(L, cfg.get("pe_features", 8))
    for i in range(depth):
        z = z @ params[f"w{i}"] + params[f"b{i}"]
        if i < depth - 1:
            # High-frequency periodic activation (Sec. 3.3): addresses the
            # low-frequency bias of MLPs so filters carry high-freq content.
            z = jnp.sin(omega * z)
    return z.T.reshape(N, D, L)


def init_implicit(key, N, D, cfg):
    return init_ffn_filter(key, N, D, cfg)


def materialize_implicit(params, N, D, L, cfg):
    """Hyena filters: FFN output × (exp-decay window + floor) (Fig. 3.1).

    Decay rates are log-spaced across channels so different channels commit
    to different memory horizons at init; the additive floor keeps filters
    from being pinned to zero past the decay length.
    """
    h = _ffn_eval(params, N, D, L, cfg)
    fast = cfg.get("decay_fast", 0.3)
    slow = cfg.get("decay_slow", 1.5)
    shift = cfg.get("window_shift", 0.01)
    t = jnp.arange(L, dtype=jnp.float32) / max(L, 1)
    alpha = jnp.exp(
        jnp.linspace(math.log(fast), math.log(slow), N * D)
    ).reshape(N, D)
    window = jnp.exp(-alpha[..., None] * t * L / (0.3 * L)) + shift
    return h * window


def init_ckconv(key, N, D, cfg):
    return init_ffn_filter(key, N, D, cfg)


def materialize_ckconv(params, N, D, L, cfg):
    return _ffn_eval(params, N, D, L, cfg)


# ---------------------------------------------------------------------------
# conv1d: explicit FIR taps (the CNN baseline).
# ---------------------------------------------------------------------------


def init_conv1d(key, N, D, cfg):
    M = cfg.get("filter_size", 64)
    return {"taps": jax.random.normal(key, (N, D, M)) * (1.0 / math.sqrt(M))}


def materialize_conv1d(params, N, D, L, cfg):
    taps = params["taps"]
    M = taps.shape[-1]
    if M >= L:
        return taps[..., :L]
    return jnp.pad(taps, ((0, 0), (0, 0), (0, L - M)))


# ---------------------------------------------------------------------------
# fno: explicit frequency-domain modes.
# ---------------------------------------------------------------------------


def init_fno(key, N, D, cfg):
    M = cfg.get("fno_modes", 64)
    k1, k2 = jax.random.split(key)
    s = 1.0 / math.sqrt(M)
    return {
        "re": jax.random.normal(k1, (N, D, M)) * s,
        "im": jax.random.normal(k2, (N, D, M)) * s,
    }


def materialize_fno(params, N, D, L, cfg):
    """Place M learned complex modes into the rfft bins of a length-L filter."""
    re, im = params["re"], params["im"]
    M = re.shape[-1]
    K = L // 2 + 1
    m = min(M, K)
    spec = jnp.zeros((N, D, K), dtype=jnp.complex64)
    spec = spec.at[..., :m].set(re[..., :m] + 1j * im[..., :m])
    return jnp.fft.irfft(spec, n=L).astype(jnp.float32)


# ---------------------------------------------------------------------------
# ssm: diagonal state-space model (S4D-lite).
# ---------------------------------------------------------------------------


def init_ssm(key, N, D, cfg):
    S = cfg.get("ssm_state", 64)
    k1, k2, k3 = jax.random.split(key, 3)
    s_idx = jnp.arange(S, dtype=jnp.float32)
    return {
        # A = -exp(log_a_re) + i·π·s  (S4D-Lin init), broadcast over (N, D).
        "log_a_re": jnp.zeros((N, D, S)) + math.log(0.5),
        "a_im": jnp.broadcast_to(math.pi * s_idx, (N, D, S)) * 1.0,
        "c_re": jax.random.normal(k1, (N, D, S)) * (1.0 / math.sqrt(S)),
        "c_im": jax.random.normal(k2, (N, D, S)) * (1.0 / math.sqrt(S)),
        # Per-channel log timestep, log-uniform in [dt_min, dt_max].
        "log_dt": jax.random.uniform(
            k3, (N, D), minval=math.log(1e-3), maxval=math.log(1e-1)
        ),
    }


def materialize_ssm(params, N, D, L, cfg):
    """h_t = Σ_s Re(C_s · exp(t · dt · A_s)) · dt  for t = 0..L−1."""
    dt = jnp.exp(params["log_dt"])[..., None]  # (N, D, 1)
    a = -jnp.exp(params["log_a_re"]) + 1j * params["a_im"]  # (N, D, S)
    c = params["c_re"] + 1j * params["c_im"]
    t = jnp.arange(L, dtype=jnp.float32)
    # (N, D, S, L) exponentials — fine at the widths used here.
    expo = jnp.exp(a[..., None] * dt[..., None] * t)
    h = jnp.einsum("nds,ndsl->ndl", c * dt, expo).real
    return h.astype(jnp.float32)


# ---------------------------------------------------------------------------
# tf: transfer function (ratio of polynomials on the unit circle).
# ---------------------------------------------------------------------------


def init_tf(key, N, D, cfg):
    M = cfg.get("tf_order", 16)
    k1, k2 = jax.random.split(key)
    return {
        "num": jax.random.normal(k1, (N, D, M)) * (1.0 / math.sqrt(M)),
        # Denominator init small → poles near origin → stable at init.
        "den": jax.random.normal(k2, (N, D, M - 1)) * 0.01,
    }


def materialize_tf(params, N, D, L, cfg):
    """h = irfft( Σ b_m z^{-m} / (1 + Σ a_m z^{-m}) ), z on the P=2L circle."""
    num, den = params["num"], params["den"]
    P = 2 * L
    K = P // 2 + 1
    w = 2.0 * math.pi * jnp.arange(K) / P
    m_num = jnp.arange(num.shape[-1], dtype=jnp.float32)
    m_den = jnp.arange(1, den.shape[-1] + 1, dtype=jnp.float32)
    zn = jnp.exp(-1j * w[None, :] * m_num[:, None])  # (M, K)
    zd = jnp.exp(-1j * w[None, :] * m_den[:, None])  # (M-1, K)
    H = jnp.einsum("ndm,mk->ndk", num.astype(jnp.complex64), zn) / (
        1.0 + jnp.einsum("ndm,mk->ndk", den.astype(jnp.complex64), zd)
    )
    h = jnp.fft.irfft(H, n=P)[..., :L]
    return h.astype(jnp.float32)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

FILTERS = {
    "implicit": (init_implicit, materialize_implicit),
    "ckconv": (init_ckconv, materialize_ckconv),
    "conv1d": (init_conv1d, materialize_conv1d),
    "fno": (init_fno, materialize_fno),
    "ssm": (init_ssm, materialize_ssm),
    "tf": (init_tf, materialize_tf),
}


def init_filter(key, kind: str, N: int, D: int, cfg) -> dict:
    return FILTERS[kind][0](key, N, D, cfg)


def materialize_filter(params, kind: str, N: int, D: int, L: int, cfg):
    return FILTERS[kind][1](params, N, D, L, cfg)
