"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the mathematical ground truth for every kernel in this package:
pytest (and hypothesis sweeps) pin the Pallas implementations against these
functions, and the L2 training graph uses them directly (they lower to the
native XLA `Fft` op, which the CPU PJRT backend executes efficiently).

Conventions
-----------
Channel-major sequence layout ``(..., D, L)`` for convolution inputs, matching
the SISO/depthwise formulation of the paper (Sec. 2): every channel has its
own length-L causal filter.
"""
from __future__ import annotations

import jax.numpy as jnp


def causal_fftconv(h: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Causal (aperiodic) convolution of filter ``h`` with signal ``v``.

    ``h``: ``(..., L)`` filter response at t = 0..L-1 (causal by construction:
    only non-negative taps are evaluated).
    ``v``: ``(..., L)`` input signal; broadcasting across leading dims.

    Zero-pads both to 2L so the circular convolution of the padded sequences
    equals the aperiodic one (paper Sec. 2, "Fast Methods for Convolutions"),
    then truncates back to L. O(L log L) via FFT.
    """
    L = v.shape[-1]
    P = 2 * L
    Hf = jnp.fft.rfft(h, n=P)
    Vf = jnp.fft.rfft(v, n=P)
    y = jnp.fft.irfft(Hf * Vf, n=P)[..., :L]
    return y.astype(v.dtype)


def fftconv_bias(h: jnp.ndarray, v: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """Causal FFT convolution with a per-channel skip term.

    ``out = (h * v) + bias ⊙ v`` — the ``D δ_t`` term of the SSM formulation
    (paper Sec. 2.1); ``bias`` broadcasts over the L axis: shape ``(D,)``
    against ``v`` of shape ``(..., D, L)``.
    """
    b = jnp.asarray(bias)
    if b.ndim == 1:
        b = b[:, None]
    return causal_fftconv(h, v) + b * v


def gated_fftconv(
    x: jnp.ndarray, h: jnp.ndarray, v: jnp.ndarray, bias: jnp.ndarray
) -> jnp.ndarray:
    """One step of the Hyena recurrence (Def. 3.1):

    ``z^{n+1} = x ⊙ ((h * z^n) + bias ⊙ z^n)``

    Shapes: ``x, v``: ``(B, D, L)``; ``h``: ``(D, L)``; ``bias``: ``(D,)``.
    This is the fused hot path the Pallas kernel implements.
    """
    return x * fftconv_bias(h, v, bias)


def short_conv(w: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal FIR convolution (Algorithm 1 step 2).

    ``w``: ``(C, F)`` per-channel filter taps (F small, typically 3).
    ``u``: ``(B, L, C)`` input.
    ``y[b, t, c] = Σ_f w[c, f] · u[b, t - f, c]`` (zero beyond the left edge).
    """
    F = w.shape[-1]
    y = jnp.zeros_like(u)
    for f in range(F):
        shifted = jnp.pad(u, ((0, 0), (f, 0), (0, 0)))[:, : u.shape[1], :]
        y = y + w[:, f] * shifted
    return y


def hyena_recurrence(
    v: jnp.ndarray, xs: jnp.ndarray, hs: jnp.ndarray, biases: jnp.ndarray
) -> jnp.ndarray:
    """Full order-N Hyena recurrence ``y = H(u) v`` (paper Eq. 4).

    ``v``: ``(B, D, L)`` value projection; ``xs``: ``(N, B, D, L)`` gates;
    ``hs``: ``(N, D, L)`` implicit long filters; ``biases``: ``(N, D)``.
    """
    N = xs.shape[0]
    z = v
    for n in range(N):
        z = gated_fftconv(xs[n], hs[n], z, biases[n])
    return z


def hyena_matrix(
    xs: jnp.ndarray, hs: jnp.ndarray, biases: jnp.ndarray
) -> jnp.ndarray:
    """Materialize the data-controlled matrix H(u) = D_x^N S_h^N … D_x^1 S_h^1.

    Single channel: ``xs``: ``(N, L)``, ``hs``: ``(N, L)``, ``biases``: ``(N,)``.
    Used only by tests / the Fig. D.2-D.4 visualization driver — O(L²) memory.
    """
    N, L = xs.shape
    t = jnp.arange(L)
    H = jnp.eye(L)
    for n in range(N):
        # Lower-triangular Toeplitz of filter n with the bias skip on its
        # diagonal (S_h + b·I), then the diagonal gate D_x.
        S = jnp.where(t[:, None] >= t[None, :], hs[n][t[:, None] - t[None, :]], 0.0)
        S = S + biases[n] * jnp.eye(L)
        H = jnp.diag(xs[n]) @ S @ H
    return H
