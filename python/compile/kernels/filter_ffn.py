"""L1 Pallas kernel: Hyena filter materialization (paper Algorithm 2).

Evaluates the sine-activated filter FFN over all L positions of the
positional encoding in one fused kernel — PE build, the MLP stack and the
exponential-decay window never round-trip to HBM. On TPU this runs as a
(L-block × hidden) chain of MXU matmuls with VPU sine activations, in
parallel across the sequence axis ("in parallel across N, L", Alg. 2).

The surrounding jax function supplies the PE matrix (iota-generated, cheap)
and the decay window; the kernel fuses Linear→sin(ω·)→…→Linear→window.
Weights are small (K×W, W×W, W×ND) and live in VMEM whole; the grid blocks
only the L axis.

Lowered with ``interpret=True``; pinned against ``filters.materialize_*``
(the jnp reference path) by pytest.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pe_ref, win_ref, *refs, depth: int, omega: float):
    """One L-block instance: z = PE; repeat Linear+sin; final Linear; window.

    ``refs`` = w0, b0, w1, b1, …, w_{depth-1}, b_{depth-1}, out_ref.
    ``pe_ref``: (Lb, De); ``win_ref``: (Lb, ND); out: (Lb, ND).
    """
    out_ref = refs[-1]
    z = pe_ref[...]
    for i in range(depth):
        w = refs[2 * i][...]
        b = refs[2 * i + 1][...]
        z = jnp.dot(z, w) + b
        if i < depth - 1:
            z = jnp.sin(omega * z)
    out_ref[...] = z * win_ref[...]


def filter_ffn_pallas(
    pe: jnp.ndarray,
    window: jnp.ndarray,
    weights: list[jnp.ndarray],
    biases: list[jnp.ndarray],
    omega: float,
    *,
    block_l: int = 256,
) -> jnp.ndarray:
    """Fused filter FFN: ``window ⊙ FFN_sine(PE)``.

    ``pe``: (L, De); ``window``: (L, ND) pre-broadcast decay window;
    ``weights[i]``: (d_i, d_{i+1}); ``biases[i]``: (d_{i+1},).
    Returns ``(L, ND)`` — the caller reshapes to (N, D, L).
    """
    L, _ = pe.shape
    ND = weights[-1].shape[-1]
    depth = len(weights)
    block_l = min(block_l, L)
    nl = -(-L // block_l)
    Lp = nl * block_l
    pe_p = jnp.pad(pe, ((0, Lp - L), (0, 0)))
    win_p = jnp.pad(window, ((0, Lp - L), (0, 0)))

    in_specs = [
        pl.BlockSpec((block_l, pe.shape[1]), lambda i: (i, 0)),    # PE block
        pl.BlockSpec((block_l, ND), lambda i: (i, 0)),             # window blk
    ]
    args = [pe_p, win_p]
    for w, b in zip(weights, biases):
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))   # whole W
        in_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))     # whole b
        args.extend([w, b])

    out = pl.pallas_call(
        functools.partial(_kernel, depth=depth, omega=omega),
        grid=(nl,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_l, ND), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Lp, ND), pe.dtype),
        interpret=True,
    )(*args)
    return out[:L]


def vmem_estimate_bytes(L_block: int, de: int, width: int, nd: int) -> int:
    """VMEM working set of one instance (f32): PE/window/out blocks + the
    whole (small) weight stack + one hidden activation block."""
    weights = de * width + 2 * width * width + width * nd + 3 * width + nd
    return 4 * (L_block * (de + 2 * nd + width) + weights)
