"""L1 Pallas kernel: depthwise causal short convolution (Algorithm 1 step 2).

Every Hyena projection is passed through a short (filter size F ≈ 3)
depthwise causal FIR filter before entering the recurrence. On TPU this is a
pure VPU (elementwise) kernel: the filter is tiny, so instead of a matmul we
compute F shifted multiply-accumulates over an (L, C) tile resident in VMEM.
The left halo is materialized by the surrounding jax function (F−1 rows of
zero padding), keeping the kernel's BlockSpec a plain disjoint tiling.

Lowered with ``interpret=True``; pinned against ``ref.short_conv``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(u_ref, w_ref, o_ref, *, F: int, L: int):
    """One batch-row instance over a padded (F-1+L, C) tile."""
    w = w_ref[...]  # (C, F)
    acc = w[:, 0] * u_ref[0, F - 1 : F - 1 + L, :]
    for f in range(1, F):
        # Tap f reads the input shifted f steps into the past; the pad
        # region supplies zeros for t < f.
        acc = acc + w[:, f] * u_ref[0, F - 1 - f : F - 1 - f + L, :]
    o_ref[0] = acc


def short_conv_pallas(w: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv: ``y[b,t,c] = Σ_f w[c,f] · u[b,t−f,c]``.

    ``w``: ``(C, F)``; ``u``: ``(B, L, C)``. F must be static (it unrolls).
    """
    B, L, C = u.shape
    F = w.shape[-1]
    up = jnp.pad(u, ((0, 0), (F - 1, 0), (0, 0)))
    import functools

    return pl.pallas_call(
        functools.partial(_kernel, F=F, L=L),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, L + F - 1, C), lambda b: (b, 0, 0)),
            pl.BlockSpec((C, F), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, C), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L, C), u.dtype),
        interpret=True,
    )(up, w)
