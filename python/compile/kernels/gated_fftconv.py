"""L1 Pallas kernel: fused gate ⊙ (long-conv + skip) — the Hyena hot path.

Hardware adaptation (DESIGN.md §Hardware-Adaptation)
----------------------------------------------------
The paper's CUDA implementation evaluates the long convolution with a fused
FFT kernel. FFT butterflies are a poor fit for the TPU MXU (the paper itself
flags FFTConv hardware utilization as the bottleneck, Sec. 3.3/4.4). We
instead evaluate the padded circular convolution as **DFT-by-matmul**:

    Vr = v · C,  Vi = v · S            (forward real DFT, two matmuls)
    Yr = Vr⊙Hr − Vi⊙Hi                 (pointwise complex product)
    Yi = Vr⊙Hi + Vi⊙Hr
    y  = Yr · A + Yi · B               (inverse real DFT, two matmuls)
    out = x ⊙ (y + bias ⊙ v)           (fused gate + skip)

where C, S, A, B are the real/imaginary (i)rfft basis matrices for padded
length P = 2L. All five stages live in one kernel instance, so the
intermediate spectra never round-trip to HBM, and >95% of the FLOPs are
MXU-shaped matmuls. The grid is (B, D/Db, K/Kb): the frequency axis is
blocked and the partial inverse transforms are accumulated into the output
block (irfft is linear over disjoint frequency bands), which bounds VMEM by
the (L × Kb) basis tiles.

Pallas is lowered with ``interpret=True`` (the CPU PJRT plugin cannot run
Mosaic custom-calls); numerics are pinned against ``ref.gated_fftconv`` by
pytest/hypothesis. VMEM footprint and MXU-utilization estimates per
BlockSpec are recorded in DESIGN.md §Perf / EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dft_bases(L: int, dtype=jnp.float32):
    """Real-DFT basis matrices for padded length P = 2L.

    Returns (C, S, A, B):
      C[t, k] =  cos(2π t k / P)            forward real part,  (L, K)
      S[t, k] = -sin(2π t k / P)            forward imag part,  (L, K)
      A[k, t] =  w_k cos(2π t k / P) / P    inverse from real,  (K, L)
      B[k, t] = -w_k sin(2π t k / P) / P    inverse from imag,  (K, L)
    with K = L + 1 rfft bins and w_0 = w_{K-1} = 1, else 2 (hermitian fold).
    Only the first L rows matter on the forward side (the pad region is
    zero) and only the first L columns on the inverse side (we truncate the
    circular result back to the causal window).

    Generated in-graph from broadcasted iota — no multi-MB constants in the
    emitted HLO text.
    """
    P = 2 * L
    K = L + 1
    t = jnp.arange(L, dtype=dtype)[:, None]
    k = jnp.arange(K, dtype=dtype)[None, :]
    ang = (2.0 * math.pi / P) * t * k
    C = jnp.cos(ang)
    S = -jnp.sin(ang)
    w = jnp.where((k == 0) | (k == K - 1), 1.0, 2.0) / P
    A = (w * jnp.cos(ang)).T
    B = (-w * jnp.sin(ang)).T
    return C, S, A, B


def _kernel(v_ref, x_ref, h_ref, b_ref, c_ref, s_ref, a_ref, bb_ref, o_ref):
    """One (batch, channel-block, frequency-block) grid instance.

    The output block doubles as the accumulator: grid iterations along the
    frequency axis are sequential and map to the same output tile, so the
    partial inverse transforms of successive bands can be summed in place;
    the last band applies the fused skip + gate.
    """
    kidx = pl.program_id(2)
    nk = pl.num_programs(2)

    v = v_ref[0]            # (Db, L)
    h = h_ref[...]          # (Db, L)
    C = c_ref[...]          # (L, Kb)
    S = s_ref[...]          # (L, Kb)

    # Forward DFT of signal and filter for this frequency band (MXU matmuls).
    vr = jnp.dot(v, C)      # (Db, Kb)
    vi = jnp.dot(v, S)
    hr = jnp.dot(h, C)
    hi = jnp.dot(h, S)

    # Pointwise complex product: the convolution theorem (paper Sec. 2).
    yr = vr * hr - vi * hi
    yi = vr * hi + vi * hr

    # Partial inverse DFT for this band.
    part = jnp.dot(yr, a_ref[...]) + jnp.dot(yi, bb_ref[...])  # (Db, L)

    @pl.when(kidx == 0)
    def _init():
        o_ref[0] = part

    @pl.when(kidx > 0)
    def _accum():
        o_ref[0] += part

    # Final band: apply the fused skip + gate.
    @pl.when(kidx == nk - 1)
    def _finish():
        o_ref[0] = x_ref[0] * (o_ref[0] + b_ref[...] * v)


def gated_fftconv_pallas(
    x: jnp.ndarray,
    h: jnp.ndarray,
    v: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    block_d: int = 16,
    block_k: int = 256,
) -> jnp.ndarray:
    """Fused Hyena recurrence step ``x ⊙ ((h * v) + bias ⊙ v)`` (Def. 3.1).

    ``x, v``: ``(B, D, L)``; ``h``: ``(D, L)``; ``bias``: ``(D,)``.
    Matches ``ref.gated_fftconv`` to ~1e-3 absolute (f32 DFT-matmul vs FFT).
    """
    Bsz, D, L = v.shape
    K = L + 1
    block_d = min(block_d, D)
    block_k = min(block_k, K)
    nd = -(-D // block_d)
    nk = -(-K // block_k)
    Dp = nd * block_d
    Kp = nk * block_k

    C, S, A, B = _dft_bases(L)
    # Pad the frequency axis to a multiple of the block: zero bands
    # contribute nothing to the accumulation. Pad channels likewise.
    C = jnp.pad(C, ((0, 0), (0, Kp - K)))
    S = jnp.pad(S, ((0, 0), (0, Kp - K)))
    A = jnp.pad(A, ((0, Kp - K), (0, 0)))
    B = jnp.pad(B, ((0, Kp - K), (0, 0)))
    padd = Dp - D
    vp = jnp.pad(v, ((0, 0), (0, padd), (0, 0)))
    xp = jnp.pad(x, ((0, 0), (0, padd), (0, 0)))
    hp = jnp.pad(h, ((0, padd), (0, 0)))
    bp = jnp.pad(jnp.asarray(bias), ((0, padd),))[:, None]  # (Dp, 1)

    out = pl.pallas_call(
        _kernel,
        grid=(Bsz, nd, nk),
        in_specs=[
            pl.BlockSpec((1, block_d, L), lambda b, d, k: (b, d, 0)),   # v
            pl.BlockSpec((1, block_d, L), lambda b, d, k: (b, d, 0)),   # x
            pl.BlockSpec((block_d, L), lambda b, d, k: (d, 0)),         # h
            pl.BlockSpec((block_d, 1), lambda b, d, k: (d, 0)),         # bias
            pl.BlockSpec((L, block_k), lambda b, d, k: (0, k)),         # C
            pl.BlockSpec((L, block_k), lambda b, d, k: (0, k)),         # S
            pl.BlockSpec((block_k, L), lambda b, d, k: (k, 0)),         # A
            pl.BlockSpec((block_k, L), lambda b, d, k: (k, 0)),         # B
        ],
        out_specs=pl.BlockSpec((1, block_d, L), lambda b, d, k: (b, d, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, Dp, L), v.dtype),
        interpret=True,
    )(vp, xp, hp, bp, C, S, A, B)
    return out[:, :D, :]


def vmem_estimate_bytes(L: int, block_d: int = 16, block_k: int = 256) -> int:
    """Estimated VMEM working set of one kernel instance (f32 bytes).

    The four basis tiles dominate (4 · L · Kb), plus the v/x/h/out channel
    blocks (4 · Db · L) and the band spectra (6 · Db · Kb). Used to pick
    block shapes so the working set fits a 16 MiB TPU VMEM.
    """
    Kb = min(block_k, L + 1)
    return 4 * (4 * L * Kb + 4 * block_d * L + 6 * block_d * Kb)


def mxu_flops(Bsz: int, D: int, L: int) -> int:
    """MXU (matmul) FLOPs: 2 signal-DFT + 2 filter-DFT + 2 inverse matmuls."""
    K = L + 1
    return 2 * (4 * Bsz * D * L * K + 2 * D * L * K)


def pointwise_flops(Bsz: int, D: int, L: int) -> int:
    """Non-MXU (VPU elementwise) FLOPs: complex product + gate + skip."""
    K = L + 1
    return Bsz * D * (6 * K + 3 * L)
