"""Sequence-mixing operators (paper Sec. 3 + baselines of Sec. 4.1).

Every operator maps ``u: (B, L, D) → y: (B, L, D)`` given a params subtree,
and exposes ``init_<kind>(key, cfg) -> params``. Operators:

``hyena``   order-N Hyena recurrence (Def. 3.1) with any filter kind
``attn``    exact causal multi-head self-attention (materialized probs)
``flash``   same math, chunked online-softmax (never materializes L×L)
``gss``     Gated State Space ≈ Hyena_1 with SSM filters (Remark 3.2)
``h3``      Hungry Hungry Hippo ≈ Hyena_2 with [shift, SSM] filters
``aft``     Attention-Free Transformer, conv variant (Zhai et al., 2021)
``rwkv``    RWKV-v4-style linear-attention recurrence (Peng, 2021)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import filters
from .kernels import ref
from .kernels.gated_fftconv import gated_fftconv_pallas
from .kernels.short_conv import short_conv_pallas


def _dense_init(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    k1, _ = jax.random.split(key)
    return jax.random.normal(k1, (d_in, d_out)) * scale


# ---------------------------------------------------------------------------
# Hyena (Def. 3.1, Algorithms 1–3)
# ---------------------------------------------------------------------------


def init_hyena(key, cfg) -> dict:
    D = cfg["width"]
    N = cfg.get("order", 2)
    F = cfg.get("short_filter", 3)
    keys = jax.random.split(key, 6)
    p = {
        "proj_w": _dense_init(keys[0], D, (N + 1) * D),
        "proj_b": jnp.zeros(((N + 1) * D,)),
        "out_w": _dense_init(keys[1], D, D),
        "out_b": jnp.zeros((D,)),
        "bias": jax.random.normal(keys[3], (N, D)) * 0.2,
    }
    if F > 0:
        # Identity-ish init: tap 0 near 1 so the block starts close to linear.
        sc = jax.random.normal(keys[2], ((N + 1) * D, F)) * 0.1
        p["short_w"] = sc.at[:, 0].add(1.0)
    fsub = filters.init_filter(keys[4], cfg.get("filter_kind", "implicit"), N, D, cfg)
    p.update({f"filter.{k}": v for k, v in fsub.items()})
    return p


def _filter_sub(p: dict) -> dict:
    return {k[len("filter."):]: v for k, v in p.items() if k.startswith("filter.")}


def hyena_op(p: dict, u: jnp.ndarray, cfg) -> jnp.ndarray:
    """Order-N Hyena forward (Algorithm 3)."""
    B, L, D = u.shape
    N = cfg.get("order", 2)
    use_pallas = cfg.get("use_pallas", False)

    # Algorithm 1: projection + depthwise short conv, split into v, x^1..x^N.
    z = u @ p["proj_w"] + p["proj_b"]  # (B, L, (N+1)D)
    if "short_w" in p:
        z = (
            short_conv_pallas(p["short_w"], z)
            if use_pallas
            else ref.short_conv(p["short_w"], z)
        )
    z = z.reshape(B, L, N + 1, D).transpose(2, 0, 3, 1)  # (N+1, B, D, L)
    v, xs = z[0], z[1:]

    # Algorithm 2: materialize implicit filters for all orders at once.
    hs = filters.materialize_filter(
        _filter_sub(p), cfg.get("filter_kind", "implicit"), N, D, L, cfg
    )

    # The recurrence (Def. 3.1): v ← x^n ⊙ (h^n * v + bias_n ⊙ v).
    step = gated_fftconv_pallas if use_pallas else ref.gated_fftconv
    for n in range(N):
        v = step(xs[n], hs[n], v, p["bias"][n])

    y = v.transpose(0, 2, 1)  # (B, L, D)
    return y @ p["out_w"] + p["out_b"]


# ---------------------------------------------------------------------------
# Exact causal multi-head attention (the quadratic baseline, Sec. 2.2)
# ---------------------------------------------------------------------------


def init_attn(key, cfg) -> dict:
    D = cfg["width"]
    keys = jax.random.split(key, 4)
    return {
        "wq": _dense_init(keys[0], D, D),
        "wk": _dense_init(keys[1], D, D),
        "wv": _dense_init(keys[2], D, D),
        "wo": _dense_init(keys[3], D, D),
    }


def _split_heads(x, H):
    B, L, D = x.shape
    return x.reshape(B, L, H, D // H).transpose(0, 2, 1, 3)  # (B, H, L, Dh)


def attn_op(p: dict, u: jnp.ndarray, cfg) -> jnp.ndarray:
    B, L, D = u.shape
    H = cfg.get("n_heads", 2)
    q = _split_heads(u @ p["wq"], H)
    k = _split_heads(u @ p["wk"], H)
    v = _split_heads(u @ p["wv"], H)
    scale = 1.0 / math.sqrt(D // H)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((L, L), dtype=bool))
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    y = y.transpose(0, 2, 1, 3).reshape(B, L, D)
    return y @ p["wo"]


def flash_attn_op(p: dict, u: jnp.ndarray, cfg) -> jnp.ndarray:
    """FlashAttention-style chunked online softmax (Dao et al., 2022b).

    Identical math to ``attn_op`` but the L×L score matrix is never
    materialized: KV is scanned in chunks with a running (max, denominator,
    numerator) triple. This is the memory-bound profile the paper benchmarks
    against in Fig. 4.3.
    """
    B, L, D = u.shape
    H = cfg.get("n_heads", 2)
    Cc = min(cfg.get("flash_chunk", 128), L)
    nchunk = -(-L // Cc)
    Lp = nchunk * Cc

    def pad(x):
        return jnp.pad(x, ((0, 0), (0, 0), (0, Lp - L), (0, 0)))

    q = pad(_split_heads(u @ p["wq"], H))  # (B, H, Lp, Dh)
    k = pad(_split_heads(u @ p["wk"], H))
    v = pad(_split_heads(u @ p["wv"], H))
    scale = 1.0 / math.sqrt(D // H)
    tq = jnp.arange(Lp)

    kc = k.reshape(B, H, nchunk, Cc, -1).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, H, nchunk, Cc, -1).transpose(2, 0, 1, 3, 4)

    def body(carry, inp):
        m, den, num = carry
        j, kj, vj = inp
        tk = j * Cc + jnp.arange(Cc)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kj) * scale  # (B, H, Lp, Cc)
        s = jnp.where(tq[None, None, :, None] >= tk[None, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        den = den * alpha + pexp.sum(axis=-1)
        num = num * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", pexp, vj)
        return (m_new, den, num), None

    Dh = D // H
    init = (
        jnp.full((B, H, Lp), -1e30),
        jnp.zeros((B, H, Lp)),
        jnp.zeros((B, H, Lp, Dh)),
    )
    (m, den, num), _ = jax.lax.scan(body, init, (jnp.arange(nchunk), kc, vc))
    y = num / jnp.maximum(den, 1e-30)[..., None]
    y = y.transpose(0, 2, 1, 3).reshape(B, Lp, D)[:, :L]
    return y @ p["wo"]


# ---------------------------------------------------------------------------
# GSS and H3 as short Hyena recurrences (Remark 3.2)
# ---------------------------------------------------------------------------


def init_gss(key, cfg) -> dict:
    c = dict(cfg, order=1, filter_kind="ssm")
    return init_hyena(key, c)


def gss_op(p, u, cfg):
    return hyena_op(p, u, dict(cfg, order=1, filter_kind="ssm"))


def init_h3(key, cfg) -> dict:
    """H3 = Hyena_2 with a shift filter (short explicit) + a diagonal SSM."""
    D = cfg["width"]
    keys = jax.random.split(key, 3)
    c = dict(cfg, order=2, filter_kind="ssm")
    p = init_hyena(keys[0], c)
    # Replace filter order 0 with explicit shift taps (Dao et al., 2022c).
    p["shift_taps"] = jax.random.normal(keys[1], (D, 4)) * 0.5
    return p


def h3_op(p, u, cfg):
    B, L, D = u.shape
    c = dict(cfg, order=2, filter_kind="ssm")
    z = u @ p["proj_w"] + p["proj_b"]
    if "short_w" in p:
        z = ref.short_conv(p["short_w"], z)
    z = z.reshape(B, L, 3, D).transpose(2, 0, 3, 1)
    v, xs = z[0], z[1:]
    hs = filters.materialize_filter(_filter_sub(p), "ssm", 2, D, L, c)
    # Order 0: shift conv (explicit short taps padded to L).
    shift = jnp.pad(p["shift_taps"], ((0, 0), (0, L - p["shift_taps"].shape[-1])))
    v = ref.gated_fftconv(xs[0], shift, v, p["bias"][0])
    # Order 1: diagonal SSM long conv.
    v = ref.gated_fftconv(xs[1], hs[1], v, p["bias"][1])
    y = v.transpose(0, 2, 1)
    return y @ p["out_w"] + p["out_b"]


# ---------------------------------------------------------------------------
# AFT-conv (Zhai et al., 2021)
# ---------------------------------------------------------------------------


def init_aft(key, cfg) -> dict:
    D = cfg["width"]
    M = cfg.get("aft_window", 64)
    keys = jax.random.split(key, 5)
    return {
        "wq": _dense_init(keys[0], D, D),
        "wk": _dense_init(keys[1], D, D),
        "wv": _dense_init(keys[2], D, D),
        "wo": _dense_init(keys[3], D, D),
        # Learned position-bias kernel w_{t-s}, one per channel (conv form).
        "pos": jax.random.normal(keys[4], (D, M)) * 0.1,
    }


def _causal_depthwise_conv(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Direct per-channel causal conv: y[:, :, t] = Σ_τ w[:, τ] · x[:, :, t−τ].

    `x` is (B, D, L), `w` is (D, M) with M ≤ L.  Exactly causal by
    construction — unlike the FFT path, no f32 round-off from future
    positions can reach the past, which matters here because the e^k
    weights span e^{±8} and amplify any leakage past test tolerance.
    """
    _, _, L = x.shape
    M = w.shape[1]
    y = w[:, 0][None, :, None] * x
    for tau in range(1, M):
        shifted = jnp.pad(x[:, :, : L - tau], ((0, 0), (0, 0), (tau, 0)))
        y = y + w[:, tau][None, :, None] * shifted
    return y


def aft_op(p: dict, u: jnp.ndarray, cfg) -> jnp.ndarray:
    """y_t = σ(q_t) ⊙ Σ_{s≤t} e^{w_{t−s} + k_s} v_s / Σ_{s≤t} e^{w_{t−s} + k_s}."""
    B, L, D = u.shape
    q = u @ p["wq"]
    k = jnp.clip(u @ p["wk"], -8.0, 8.0)
    v = u @ p["wv"]
    ek = jnp.exp(k).transpose(0, 2, 1)  # (B, D, L)
    ev = (jnp.exp(k) * v).transpose(0, 2, 1)
    w = jnp.exp(p["pos"])[:, :L]  # (D, min(M, L)) position-bias taps
    num = _causal_depthwise_conv(w, ev)
    den = _causal_depthwise_conv(w, ek)
    y = (num / jnp.maximum(den, 1e-6)).transpose(0, 2, 1)
    return (jax.nn.sigmoid(q) * y) @ p["wo"]


# ---------------------------------------------------------------------------
# RWKV-v4-lite (Peng, 2021)
# ---------------------------------------------------------------------------


def init_rwkv(key, cfg) -> dict:
    D = cfg["width"]
    keys = jax.random.split(key, 5)
    return {
        "wr": _dense_init(keys[0], D, D),
        "wk": _dense_init(keys[1], D, D),
        "wv": _dense_init(keys[2], D, D),
        "wo": _dense_init(keys[3], D, D),
        # Per-channel decay (positive via softplus) and first-token bonus.
        "decay": jnp.linspace(-1.0, 2.0, D),
        "bonus": jnp.zeros((D,)),
    }


def rwkv_op(p: dict, u: jnp.ndarray, cfg) -> jnp.ndarray:
    """Linear-attention recurrence: exponential-decay weighted kv average."""
    B, L, D = u.shape
    r = jax.nn.sigmoid(u @ p["wr"])
    k = jnp.clip(u @ p["wk"], -8.0, 8.0)
    v = u @ p["wv"]
    wdecay = jnp.exp(-jax.nn.softplus(p["decay"]))  # (D,) in (0, 1)
    bonus = jnp.exp(p["bonus"])

    def step(carry, inp):
        a, b = carry  # numerator / denominator state, (B, D)
        kt, vt = inp
        ekt = jnp.exp(kt)
        out = (a + bonus * ekt * vt) / (b + bonus * ekt + 1e-6)
        a = wdecay * a + ekt * vt
        b = wdecay * b + ekt
        return (a, b), out

    k_t = k.transpose(1, 0, 2)  # (L, B, D)
    v_t = v.transpose(1, 0, 2)
    init = (jnp.zeros((B, D)), jnp.zeros((B, D)))
    _, wkv = jax.lax.scan(step, init, (k_t, v_t))
    y = r * wkv.transpose(1, 0, 2)
    return y @ p["wo"]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

OPS = {
    "hyena": (init_hyena, hyena_op),
    "attn": (init_attn, attn_op),
    "flash": (init_attn, flash_attn_op),
    "gss": (init_gss, gss_op),
    "h3": (init_h3, h3_op),
    "aft": (init_aft, aft_op),
    "rwkv": (init_rwkv, rwkv_op),
}


def init_op(key, kind: str, cfg) -> dict:
    return OPS[kind][0](key, cfg)


def apply_op(params: dict, kind: str, u: jnp.ndarray, cfg) -> jnp.ndarray:
    return OPS[kind][1](params, u, cfg)
