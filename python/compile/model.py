"""L2 models: decoder-only language model + ViT-style image classifier.

The LM follows the standard GPT skeleton (pre-LN blocks, GELU MLP, learned
positional embeddings, untied head) with the token mixer swapped per config —
exactly the paper's drop-in protocol (Sec. 4.2, 4.5). Params are a flat
``dict[str, array]`` with dotted keys; AOT flattening order is the sorted key
order (see aot.py / the Rust manifest).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import ops


# ---------------------------------------------------------------------------
# shared nn pieces
# ---------------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _prefix(sub: dict, name: str) -> dict:
    return {f"{name}.{k}": v for k, v in sub.items()}


def _sub(params: dict, name: str) -> dict:
    pre = name + "."
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


def init_block(key, cfg) -> dict:
    D = cfg["width"]
    Dm = int(D * cfg.get("mlp_ratio", 4))
    keys = jax.random.split(key, 3)
    p = {}
    p.update(_prefix(ops.init_op(keys[0], cfg["mixer"], cfg), "mixer"))
    p["ln1.g"] = jnp.ones((D,))
    p["ln1.b"] = jnp.zeros((D,))
    p["ln2.g"] = jnp.ones((D,))
    p["ln2.b"] = jnp.zeros((D,))
    p["mlp.w1"] = jax.random.normal(keys[1], (D, Dm)) / math.sqrt(D)
    p["mlp.b1"] = jnp.zeros((Dm,))
    p["mlp.w2"] = jax.random.normal(keys[2], (Dm, D)) / math.sqrt(Dm)
    p["mlp.b2"] = jnp.zeros((D,))
    return p


def block_fwd(p: dict, u: jnp.ndarray, cfg) -> jnp.ndarray:
    h = u + ops.apply_op(
        _sub(p, "mixer"), cfg["mixer"], layer_norm(u, p["ln1.g"], p["ln1.b"]), cfg
    )
    z = layer_norm(h, p["ln2.g"], p["ln2.b"])
    z = jax.nn.gelu(z @ p["mlp.w1"] + p["mlp.b1"]) @ p["mlp.w2"] + p["mlp.b2"]
    return h + z


# ---------------------------------------------------------------------------
# language model
# ---------------------------------------------------------------------------


def init_lm(seed, cfg) -> dict:
    """Initialize LM params from an (optionally traced) u32 seed."""
    key = jax.random.PRNGKey(seed)
    V, D, L = cfg["vocab"], cfg["width"], cfg["seqlen"]
    depth = cfg["depth"]
    keys = jax.random.split(key, depth + 3)
    p = {
        "embed": jax.random.normal(keys[0], (V, D)) * 0.02,
        "pos": jax.random.normal(keys[1], (L, D)) * 0.01,
        "lnf.g": jnp.ones((D,)),
        "lnf.b": jnp.zeros((D,)),
        "head": jax.random.normal(keys[2], (D, V)) * 0.02,
    }
    for i in range(depth):
        p.update(_prefix(init_block(keys[3 + i], cfg), f"blocks.{i}"))
    return p


def forward_lm(params: dict, tokens: jnp.ndarray, cfg) -> jnp.ndarray:
    """tokens ``(B, L) int32`` → logits ``(B, L, V)``."""
    L = tokens.shape[1]
    u = params["embed"][tokens] + params["pos"][:L]
    for i in range(cfg["depth"]):
        u = block_fwd(_sub(params, f"blocks.{i}"), u, cfg)
    u = layer_norm(u, params["lnf.g"], params["lnf.b"])
    return u @ params["head"]


def lm_loss(params, tokens, targets, mask, cfg):
    """Masked autoregressive cross-entropy (mean over unmasked positions)."""
    logits = forward_lm(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# image classifier (ViT / Hyena-ViT, Sec. 4.5)
# ---------------------------------------------------------------------------


def init_img(seed, cfg) -> dict:
    key = jax.random.PRNGKey(seed)
    D = cfg["width"]
    pd = cfg["patch"] * cfg["patch"] * cfg.get("channels", 1)
    depth = cfg["depth"]
    keys = jax.random.split(key, depth + 3)
    p = {
        "patch_w": jax.random.normal(keys[0], (pd, D)) / math.sqrt(pd),
        "patch_b": jnp.zeros((D,)),
        "lnf.g": jnp.ones((D,)),
        "lnf.b": jnp.zeros((D,)),
        "head": jax.random.normal(keys[2], (D, cfg["classes"])) * 0.02,
    }
    if cfg["mixer"] in ("attn", "flash"):
        # Hyena-ViT drops positional embeddings (App. A.4); attention needs them.
        p["pos"] = jax.random.normal(keys[1], (cfg["seqlen"], D)) * 0.01
    for i in range(depth):
        p.update(_prefix(init_block(keys[3 + i], cfg), f"blocks.{i}"))
    return p


def patchify(images: jnp.ndarray, patch: int) -> jnp.ndarray:
    """``(B, H, W) → (B, (H/p)·(W/p), p·p)`` row-major patch sequence."""
    B, H, W = images.shape
    ph, pw = H // patch, W // patch
    x = images.reshape(B, ph, patch, pw, patch)
    return x.transpose(0, 1, 3, 2, 4).reshape(B, ph * pw, patch * patch)


def forward_img(params: dict, images: jnp.ndarray, cfg) -> jnp.ndarray:
    """images ``(B, H, W) f32`` → logits ``(B, classes)``."""
    u = patchify(images, cfg["patch"]) @ params["patch_w"] + params["patch_b"]
    if "pos" in params:
        u = u + params["pos"][: u.shape[1]]
    for i in range(cfg["depth"]):
        u = block_fwd(_sub(params, f"blocks.{i}"), u, cfg)
    u = layer_norm(u, params["lnf.g"], params["lnf.b"])
    return u.mean(axis=1) @ params["head"]


def img_loss(params, images, labels, cfg):
    logits = forward_img(params, images, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


# ---------------------------------------------------------------------------
# FLOP accounting (paper App. A.2)
# ---------------------------------------------------------------------------


def flops_per_token_lm(cfg) -> float:
    """Forward FLOPs per token, paper App. A.2 formulas (×2 for mul+add).

    Attention blocks: 4 projections + the non-parametric attention-matrix
    FLOPs (2·L·D for QK^T and AV each). Hyena blocks: (N+1) projections +
    short conv + FFTConv term ``5·(order)·D·log2(L)`` + output projection.
    """
    D, L, V = cfg["width"], cfg["seqlen"], cfg["vocab"]
    N = cfg.get("order", 2)
    mlp = 2 * 2 * D * int(D * cfg.get("mlp_ratio", 4))
    emb_head = 2 * D * V
    if cfg["mixer"] in ("attn", "flash"):
        mixer = 2 * 4 * D * D + 2 * 2 * L * D  # param + non-param (per token)
    else:
        proj = 2 * (N + 1) * D * D
        short = 2 * (N + 1) * D * cfg.get("short_filter", 3)
        fftconv = 2 * 5 * N * D * math.log2(max(L, 2))
        out = 2 * D * D
        mixer = proj + short + fftconv + out
    return cfg["depth"] * (mixer + mlp) + emb_head


def flops_per_step(cfg, batch: int) -> float:
    """Training-step FLOPs ≈ 3× forward (fwd + bwd) × tokens."""
    return 3.0 * flops_per_token_lm(cfg) * batch * cfg["seqlen"]


def param_count(params: dict) -> int:
    return int(sum(v.size for v in params.values()))
