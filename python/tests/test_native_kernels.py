"""Numpy mirrors of the native backend's SIMD kernel subsystem.

The Rust dispatch tables (rust/src/backend/native/kernels/) cannot be
executed in a container without cargo, so this module mirrors their
algorithms 1:1 in float32 numpy and pins the DESIGN.md §Kernels numerics
contract. numpy-only on purpose: unlike test_kernels.py (jax + hypothesis),
it runs on a bare python3 + numpy image.
"""
import numpy as np

# ---------------------------------------------------------------------------
# Native-backend SIMD kernel mirrors (numpy-only — no jax below this line).
#
# The Rust SIMD tables (rust/src/backend/native/kernels/{simd,neon}.rs)
# cannot be executed here (no cargo in this container), so these tests
# mirror their *algorithms* 1:1 in float32 numpy and pin the numerics
# contract of DESIGN.md §Kernels:
#   * the Cephes-style polynomial exp/tanh behind the SIMD GELU agrees with
#     libm to well inside the 1e-5 kernel contract,
#   * the paired-lane dot (f32 lane partials reduced in f64) is at least as
#     tight as the serial f32 dot against an exact f64 reference,
#   * lane-blocked butterfly stages are bitwise the scalar stage (no
#     accumulation ⇒ reassociation-free).
# ---------------------------------------------------------------------------

F32 = np.float32

_EXP_HI = F32(88.3762626647950)
_EXP_LO = F32(-88.3762626647949)
_LOG2EF = F32(1.44269504088896341)
_EXP_C1 = F32(0.693359375)
_EXP_C2 = F32(-2.12194440e-4)
_EXP_P = [
    F32(1.9875691500e-4),
    F32(1.3981999507e-3),
    F32(8.3334519073e-3),
    F32(4.1665795894e-2),
    F32(1.6666665459e-1),
    F32(5.0000001201e-1),
]
_GELU_C = F32(0.7978846)
_GELU_A = F32(0.044715)


def _exp_poly_f32(x):
    """1:1 float32 mirror of `exp256` in kernels/simd.rs (same op order)."""
    x = np.clip(np.asarray(x, F32), _EXP_LO, _EXP_HI)
    fx = np.floor(x * _LOG2EF + F32(0.5)).astype(F32)
    r = ((x - fx * _EXP_C1) - fx * _EXP_C2).astype(F32)
    z = (r * r).astype(F32)
    y = np.full_like(r, _EXP_P[0])
    for p in _EXP_P[1:]:
        y = (y * r + p).astype(F32)
    y = (y * z + r + F32(1.0)).astype(F32)
    n = fx.astype(np.int32)
    pow2n = np.left_shift(n + np.int32(127), 23).view(F32)
    return (y * pow2n).astype(F32)


def _tanh_poly_f32(x):
    """1:1 float32 mirror of `tanh256`: sign(x)·(1 − 2/(e^{2|x|}+1))."""
    x = np.asarray(x, F32)
    ax = np.abs(x)
    e = _exp_poly_f32(ax + ax)
    t = (F32(1.0) - (F32(2.0) / (e + F32(1.0))).astype(F32)).astype(F32)
    return np.copysign(t, x).astype(F32)


class TestSimdKernelMirrors:
    def test_poly_exp_matches_libm(self):
        # Domain note: near the negative clamp (x ≲ −87) the result is
        # subnormal in f32 and the 2^n exponent scaling flushes to zero —
        # the classic Cephes edge. The tanh path only ever evaluates
        # exp(2|x|) ≥ 1, so the kernel never sees that regime; the mirror
        # pins the regime it does use: [−80, 88.37].
        rng = np.random.default_rng(0)
        x = np.concatenate(
            [
                rng.normal(0.0, 3.0, 4096),
                np.linspace(-80.0, 88.0, 512),
                np.array([0.0, -0.0, 1e-6, -1e-6, 88.37]),
            ]
        ).astype(F32)
        got = _exp_poly_f32(x).astype(np.float64)
        want = np.exp(x.astype(np.float64))
        rel = np.abs(got - want) / np.maximum(want, 1e-300)
        assert rel.max() < 1e-6, f"poly exp drifted: {rel.max()}"

    def test_poly_tanh_and_gelu_meet_kernel_contract(self):
        rng = np.random.default_rng(1)
        v = np.concatenate(
            [
                rng.normal(0.0, 2.0, 4096),
                np.linspace(-12.0, 12.0, 512),
                np.array([0.0, 1e-4, -1e-4, 50.0, -50.0]),
            ]
        ).astype(F32)
        inner = (_GELU_C * (v + _GELU_A * ((v * v) * v))).astype(F32)
        t = _tanh_poly_f32(inner).astype(np.float64)
        t_ref = np.tanh(inner.astype(np.float64))
        rel_t = np.abs(t - t_ref) / (1.0 + np.maximum(np.abs(t), np.abs(t_ref)))
        assert rel_t.max() < 1e-5, f"poly tanh drifted: {rel_t.max()}"
        # GELU output under the same 1e-5 relative contract.
        y = (F32(0.5) * v * (F32(1.0) + t.astype(F32))).astype(np.float64)
        y_ref = 0.5 * v.astype(np.float64) * (1.0 + t_ref)
        rel_y = np.abs(y - y_ref) / (1.0 + np.maximum(np.abs(y), np.abs(y_ref)))
        assert rel_y.max() < 1e-5, f"poly gelu drifted: {rel_y.max()}"
        # tanh saturates monotonically to ±1 (no polynomial blow-up).
        assert abs(float(_tanh_poly_f32(np.array([30.0], F32))[0]) - 1.0) < 1e-7
        assert abs(float(_tanh_poly_f32(np.array([-30.0], F32))[0]) + 1.0) < 1e-7

    @staticmethod
    def _dot_paired_lanes(a, b, lanes=8):
        """1:1 mirror of `dot_avx2`: two f32 lane accumulators (16/iter),
        one more 8-wide pass, f64 reduction of lanes + scalar tail."""
        a = np.asarray(a, F32)
        b = np.asarray(b, F32)
        n = len(a)
        acc0 = np.zeros(lanes, F32)
        acc1 = np.zeros(lanes, F32)
        i = 0
        while i + 2 * lanes <= n:
            acc0 = (acc0 + (a[i : i + lanes] * b[i : i + lanes]).astype(F32)).astype(F32)
            acc1 = (
                acc1
                + (a[i + lanes : i + 2 * lanes] * b[i + lanes : i + 2 * lanes]).astype(F32)
            ).astype(F32)
            i += 2 * lanes
        if i + lanes <= n:
            acc0 = (acc0 + (a[i : i + lanes] * b[i : i + lanes]).astype(F32)).astype(F32)
            i += lanes
        s = float(acc0.astype(np.float64).sum() + acc1.astype(np.float64).sum())
        for k in range(i, n):
            s += float(a[k]) * float(b[k])
        return F32(s)

    def test_paired_lane_dot_is_no_looser_than_serial_f32(self):
        rng = np.random.default_rng(2)
        d = 8192
        # Positive operands: condition number ~1, the audit's regime.
        a = (0.5 + 0.5 * rng.random(d)).astype(F32)
        b = (0.5 + 0.5 * rng.random(d)).astype(F32)
        exact = float(a.astype(np.float64) @ b.astype(np.float64))
        serial = F32(0.0)
        for k in range(d):
            serial = F32(serial + F32(a[k] * b[k]))
        err_serial = abs(float(serial) - exact) / exact
        err_lanes = abs(float(self._dot_paired_lanes(a, b)) - exact) / exact
        assert err_serial < 5e-4, f"serial f32 dot out of audit bounds: {err_serial}"
        assert err_lanes <= err_serial + 1e-7, (
            f"paired-lane dot looser than serial: {err_lanes} vs {err_serial}"
        )
        # Tail handling: non-multiple-of-lane lengths agree with f64 tightly.
        for n in [1, 7, 17, 100]:
            x, y = a[:n], b[:n]
            want = float(x.astype(np.float64) @ y.astype(np.float64))
            got = float(self._dot_paired_lanes(x, y))
            assert abs(got - want) / (1.0 + abs(want)) < 1e-6

    @staticmethod
    def _butterfly_stage(re, im, tw_re, tw_im, length, inverse, block=None):
        """Mirror of `butterfly_pass`: scalar when block is None, else
        lane-blocked in chunks of `block` (vector path)."""
        re, im = re.copy(), im.copy()
        n = len(re)
        step = n // length
        half = length // 2
        for start in range(0, n, length):
            ks = 0
            if block is not None:
                while ks + block <= half:
                    idx = np.arange(ks, ks + block)
                    wr = tw_re[idx * step]
                    wi = (-tw_im[idx * step] if inverse else tw_im[idx * step]).astype(F32)
                    a, b = start + idx, start + idx + half
                    tr = (re[b] * wr - im[b] * wi).astype(F32)
                    ti = (re[b] * wi + im[b] * wr).astype(F32)
                    re[b] = (re[a] - tr).astype(F32)
                    im[b] = (im[a] - ti).astype(F32)
                    re[a] = (re[a] + tr).astype(F32)
                    im[a] = (im[a] + ti).astype(F32)
                    ks += block
            for k in range(ks, half):
                wr = tw_re[k * step]
                wi = F32(-tw_im[k * step]) if inverse else tw_im[k * step]
                a, b = start + k, start + k + half
                tr = F32(re[b] * wr - im[b] * wi)
                ti = F32(re[b] * wi + im[b] * wr)
                re[b], im[b] = F32(re[a] - tr), F32(im[a] - ti)
                re[a], im[a] = F32(re[a] + tr), F32(im[a] + ti)
        return re, im

    def test_lane_blocked_butterflies_are_bitwise_scalar(self):
        rng = np.random.default_rng(3)
        n = 256
        k = np.arange(n // 2)
        tw_re = np.cos(-2.0 * np.pi * k / n).astype(F32)
        tw_im = np.sin(-2.0 * np.pi * k / n).astype(F32)
        re = rng.normal(size=n).astype(F32)
        im = rng.normal(size=n).astype(F32)
        for inverse in [False, True]:
            length = 2
            while length <= n:
                s_re, s_im = self._butterfly_stage(re, im, tw_re, tw_im, length, inverse)
                v_re, v_im = self._butterfly_stage(
                    re, im, tw_re, tw_im, length, inverse, block=8
                )
                assert np.array_equal(s_re, v_re), f"re diverged at len={length}"
                assert np.array_equal(s_im, v_im), f"im diverged at len={length}"
                length <<= 1
