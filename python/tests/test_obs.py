"""Numpy mirror of the telemetry registry (rust/src/obs/mod.rs).

Pins the two numeric contracts of the metrics subsystem so they stay
executable in cargo-less containers:

* **log2 bucketing** — `bucket_index` maps an observation to the smallest
  i with v <= 2^i (v = 0 and 1 share bucket 0; everything past 2^30 lands
  in +Inf). The mirror is checked exhaustively at every boundary and
  against a brute-force definition on random draws.
* **quantiles** — a log2 histogram only knows bucket edges, so the best
  upper bound for quantile q is the upper edge of the bucket where the
  cumulative count crosses q. That bound must bracket the true numpy
  percentile from above within a factor of 2 (the bucket width contract).

Plus the **golden exposition** test: a fixed snapshot rendered through the
python mirror of `render_prometheus` must equal the golden text
byte-for-byte (HELP/TYPE once per family, series in (name, labels) order,
cumulative buckets, the `+Inf`/`_sum`/`_count` contract, label escaping).

Pure numpy; no repo imports, no jax, no hypothesis.
"""
import numpy as np

HIST_BUCKETS = 32  # le = 2^0 .. 2^30 (31 finite bounds) + +Inf


def bucket_index(v):
    """Mirror of obs::bucket_index."""
    if v <= 1:
        return 0
    return min(int(v - 1).bit_length(), HIST_BUCKETS - 1)


def bucket_le(i):
    """Mirror of obs::bucket_le: upper bound, None for +Inf."""
    return (1 << i) if i + 1 < HIST_BUCKETS else None


def brute_index(v):
    """The definitional spelling: smallest i with v <= 2^i, clamped."""
    for i in range(HIST_BUCKETS - 1):
        if v <= (1 << i):
            return i
    return HIST_BUCKETS - 1


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------


def test_bucket_boundaries():
    assert bucket_index(0) == 0
    assert bucket_index(1) == 0
    assert bucket_index(2) == 1
    assert bucket_index(3) == 2
    assert bucket_index(4) == 2
    assert bucket_index(5) == 3
    assert bucket_index(1 << 30) == 30
    assert bucket_index((1 << 30) + 1) == HIST_BUCKETS - 1
    assert bucket_index(2**64 - 1) == HIST_BUCKETS - 1
    # Every finite bound is the largest value of its own bucket.
    for i in range(HIST_BUCKETS - 1):
        assert bucket_index(1 << i) == i
        assert bucket_index((1 << i) + 1) == min(i + 1, HIST_BUCKETS - 1)


def test_bucket_index_matches_brute_force():
    rng = np.random.default_rng(0)
    draws = rng.integers(0, 2**40, size=2000)
    for v in draws.tolist():
        assert bucket_index(v) == brute_index(v)


def test_bucket_le_contract():
    assert bucket_le(0) == 1
    assert bucket_le(1) == 2
    assert bucket_le(HIST_BUCKETS - 2) == 1 << (HIST_BUCKETS - 2)
    assert bucket_le(HIST_BUCKETS - 1) is None
    # A value in bucket i obeys le(i-1) < v <= le(i).
    for v in [1, 2, 3, 100, 4097, 10**6]:
        i = bucket_index(v)
        assert v <= bucket_le(i)
        if i > 0:
            assert v > bucket_le(i - 1)


# ---------------------------------------------------------------------------
# quantiles from cumulative buckets vs numpy
# ---------------------------------------------------------------------------


def histogram_counts(samples):
    counts = np.zeros(HIST_BUCKETS, dtype=np.int64)
    for v in samples:
        counts[bucket_index(int(v))] += 1
    return counts


def quantile_upper_bound(counts, q):
    """Quantile estimate a scraper computes from the cumulative buckets:
    the upper edge of the first bucket whose cumulative count reaches
    q * total. Inf if the crossing is in the +Inf bucket."""
    total = counts.sum()
    assert total > 0
    need = q * total
    cum = 0
    for i in range(HIST_BUCKETS):
        cum += counts[i]
        if cum >= need:
            le = bucket_le(i)
            return float(le) if le is not None else float("inf")
    return float("inf")


def test_quantile_bound_brackets_numpy():
    rng = np.random.default_rng(7)
    # Log-uniform latencies: 1us .. ~1s in microseconds, the histogram's
    # intended operating range.
    samples = np.exp(rng.uniform(0, np.log(1e6), size=5000)).astype(np.int64)
    samples = np.maximum(samples, 1)
    counts = histogram_counts(samples)
    assert counts.sum() == len(samples)
    for q in (0.5, 0.9, 0.99):
        est = quantile_upper_bound(counts, q)
        # Nearest-rank true quantile.
        true = float(np.sort(samples)[int(np.ceil(q * len(samples))) - 1])
        # The bucket containing the true quantile has edges (le/2, le]:
        # the estimate is an upper bound, and tight within a factor of 2.
        assert est >= true
        assert est < 2.0 * true + 1e-9


def test_quantile_bound_exact_at_bucket_edges():
    # All mass at exact powers of two: the bound is exact.
    samples = [1] * 50 + [4] * 30 + [64] * 20
    counts = histogram_counts(samples)
    assert quantile_upper_bound(counts, 0.5) == 1.0
    assert quantile_upper_bound(counts, 0.8) == 4.0
    assert quantile_upper_bound(counts, 1.0) == 64.0


def test_merge_is_bucketwise_addition():
    rng = np.random.default_rng(3)
    a = rng.integers(1, 10**6, size=800)
    b = rng.integers(1, 10**6, size=700)
    merged = histogram_counts(a) + histogram_counts(b)
    both = histogram_counts(np.concatenate([a, b]))
    assert np.array_equal(merged, both)


# ---------------------------------------------------------------------------
# golden Prometheus exposition
# ---------------------------------------------------------------------------


def escape_label(v):
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def escape_help(v):
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def label_block(labels, extra=None):
    parts = ['%s="%s"' % (k, escape_label(v)) for k, v in labels]
    if extra is not None:
        parts.append('%s="%s"' % (extra[0], escape_label(extra[1])))
    return "{%s}" % ",".join(parts) if parts else ""


def render_prometheus(series):
    """Mirror of obs::render_prometheus over (name, help, labels, kind,
    value) tuples, pre-sorted by (name, labels) like obs::sort_series."""
    out = []
    last_family = None
    for name, help_, labels, kind, value in series:
        if last_family != name:
            out.append("# HELP %s %s\n" % (name, escape_help(help_)))
            out.append("# TYPE %s %s\n" % (name, kind))
            last_family = name
        if kind in ("counter", "gauge"):
            out.append("%s%s %d\n" % (name, label_block(labels), value))
        else:  # histogram: (buckets, sum, count)
            buckets, total, count = value
            cum = 0
            for i, b in enumerate(buckets):
                cum += b
                le = bucket_le(i)
                le_s = str(le) if le is not None else "+Inf"
                out.append(
                    "%s_bucket%s %d\n"
                    % (name, label_block(labels, ("le", le_s)), cum)
                )
            out.append("%s_sum%s %d\n" % (name, label_block(labels), total))
            out.append("%s_count%s %d\n" % (name, label_block(labels), count))
    return "".join(out)


def test_golden_exposition():
    buckets = [0] * HIST_BUCKETS
    buckets[bucket_index(1)] += 1      # le=1
    buckets[bucket_index(3)] += 1      # le=4
    buckets[bucket_index(2**40)] += 1  # +Inf
    series = [
        ("hyena_http_responses_total", "HTTP responses by status class",
         [("class", "2xx")], "counter", 7),
        ("hyena_http_responses_total", "HTTP responses by status class",
         [("class", "4xx")], "counter", 2),
        ("hyena_inflight_requests", "Generate requests currently admitted",
         [], "gauge", 3),
        ("hyena_ttfb_us", "Time to first token event, microseconds",
         [], "histogram", (buckets, 4 + 2**40, 3)),
    ]
    text = render_prometheus(series)
    # Family headers appear once, even for multi-series families.
    assert text.count("# HELP hyena_http_responses_total") == 1
    assert text.count("# TYPE hyena_http_responses_total counter") == 1
    # Golden lines (the exact text the Rust renderer emits — see the
    # histogram_exposition_contract test in rust/src/obs/mod.rs).
    assert 'hyena_http_responses_total{class="2xx"} 7\n' in text
    assert 'hyena_http_responses_total{class="4xx"} 2\n' in text
    assert "hyena_inflight_requests 3\n" in text
    assert 'hyena_ttfb_us_bucket{le="1"} 1\n' in text
    assert 'hyena_ttfb_us_bucket{le="4"} 2\n' in text   # cumulative
    assert 'hyena_ttfb_us_bucket{le="+Inf"} 3\n' in text
    assert "hyena_ttfb_us_sum %d\n" % (4 + 2**40) in text
    assert "hyena_ttfb_us_count 3\n" in text
    # Full golden: deterministic end-to-end text.
    golden = (
        "# HELP hyena_http_responses_total HTTP responses by status class\n"
        "# TYPE hyena_http_responses_total counter\n"
        'hyena_http_responses_total{class="2xx"} 7\n'
        'hyena_http_responses_total{class="4xx"} 2\n'
        "# HELP hyena_inflight_requests Generate requests currently admitted\n"
        "# TYPE hyena_inflight_requests gauge\n"
        "hyena_inflight_requests 3\n"
        "# HELP hyena_ttfb_us Time to first token event, microseconds\n"
        "# TYPE hyena_ttfb_us histogram\n"
    )
    assert text.startswith(golden)


def test_exposition_escapes_labels():
    series = [
        ("hyena_esc_total", "back\\slash help", [("path", 'a"b\\c\nd')],
         "counter", 1),
    ]
    text = render_prometheus(series)
    assert "# HELP hyena_esc_total back\\\\slash help\n" in text
    assert 'hyena_esc_total{path="a\\"b\\\\c\\nd"} 1\n' in text
