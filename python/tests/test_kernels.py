"""L1 kernel correctness: Pallas implementations vs pure-jnp oracles.

The CORE correctness signal of the compile path: hypothesis sweeps shapes,
block sizes and dtypes; every case asserts allclose against ref.py.
"""
import numpy as np
import pytest

# Containers without the compile-path extras (jax, hypothesis) must skip this
# module cleanly at collection time instead of failing with ImportError.
jax = pytest.importorskip("jax", reason="compile-path tests need jax")
pytest.importorskip("hypothesis", reason="compile-path tests need hypothesis")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gated_fftconv import (
    gated_fftconv_pallas,
    mxu_flops,
    pointwise_flops,
    vmem_estimate_bytes,
)
from compile.kernels.short_conv import short_conv_pallas

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


class TestGatedFftconv:
    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        d=st.integers(1, 20),
        logl=st.integers(2, 7),
        block_d=st.sampled_from([4, 8, 16]),
        block_k=st.sampled_from([8, 32, 256]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, b, d, logl, block_d, block_k, seed):
        L = 2**logl
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        v = _rand(ks[0], b, d, L)
        x = _rand(ks[1], b, d, L)
        h = _rand(ks[2], d, L) * 0.3
        bias = _rand(ks[3], d)
        want = ref.gated_fftconv(x, h, v, bias)
        got = gated_fftconv_pallas(x, h, v, bias, block_d=block_d, block_k=block_k)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-3)

    def test_causality(self):
        """Perturbing input at position t must not change outputs before t."""
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        B, D, L, t = 1, 4, 32, 17
        v = _rand(ks[0], B, D, L)
        x = _rand(ks[1], B, D, L)
        h = _rand(ks[2], D, L)
        bias = _rand(ks[3], D)
        y0 = gated_fftconv_pallas(x, h, v, bias)
        v2 = v.at[:, :, t].add(10.0)
        y1 = gated_fftconv_pallas(x, h, v2, bias)
        np.testing.assert_allclose(y0[:, :, :t], y1[:, :, :t], atol=1e-4)
        assert float(jnp.abs(y0[:, :, t:] - y1[:, :, t:]).max()) > 1e-3

    def test_identity_filter(self):
        """h = δ_0, bias = 0, x = 1 → the operator is the identity."""
        B, D, L = 2, 3, 16
        v = _rand(jax.random.PRNGKey(1), B, D, L)
        h = jnp.zeros((D, L)).at[:, 0].set(1.0)
        y = gated_fftconv_pallas(jnp.ones_like(v), h, v, jnp.zeros(D))
        np.testing.assert_allclose(y, v, rtol=1e-3, atol=1e-3)

    def test_pure_skip(self):
        """h = 0 → out = x ⊙ bias ⊙ v exactly (the D δ_t term)."""
        B, D, L = 1, 5, 8
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        v, x = _rand(ks[0], B, D, L), _rand(ks[1], B, D, L)
        bias = _rand(ks[2], D)
        y = gated_fftconv_pallas(x, jnp.zeros((D, L)), v, bias)
        np.testing.assert_allclose(y, x * bias[:, None] * v, rtol=1e-3, atol=1e-3)

    def test_ragged_blocks(self):
        """D and K not divisible by the block sizes (padding path)."""
        B, D, L = 2, 7, 32
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        v, x = _rand(ks[0], B, D, L), _rand(ks[1], B, D, L)
        h, bias = _rand(ks[2], D, L), _rand(ks[3], D)
        want = ref.gated_fftconv(x, h, v, bias)
        got = gated_fftconv_pallas(x, h, v, bias, block_d=4, block_k=10)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-3)

    def test_vmem_estimate_monotone(self):
        assert vmem_estimate_bytes(2048) > vmem_estimate_bytes(256)
        # Default blocks keep the working set under a 16 MiB TPU VMEM @ L=2048.
        assert vmem_estimate_bytes(2048, 16, 256) < 16 * 2**20

    def test_flop_split_is_matmul_dominated(self):
        assert mxu_flops(4, 64, 1024) > 50 * pointwise_flops(4, 64, 1024)


class TestShortConv:
    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        l=st.integers(1, 40),
        c=st.integers(1, 12),
        f=st.integers(1, 5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, b, l, c, f, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        w = _rand(ks[0], c, f)
        u = _rand(ks[1], b, l, c)
        np.testing.assert_allclose(
            short_conv_pallas(w, u), ref.short_conv(w, u), rtol=1e-4, atol=1e-5
        )

    def test_identity_taps(self):
        u = _rand(jax.random.PRNGKey(0), 2, 9, 4)
        w = jnp.zeros((4, 3)).at[:, 0].set(1.0)
        np.testing.assert_allclose(short_conv_pallas(w, u), u, atol=1e-6)

    def test_delay_taps(self):
        """w = δ_1 shifts the sequence right by one step."""
        u = _rand(jax.random.PRNGKey(1), 1, 6, 2)
        w = jnp.zeros((2, 3)).at[:, 1].set(1.0)
        y = short_conv_pallas(w, u)
        np.testing.assert_allclose(y[:, 1:], u[:, :-1], atol=1e-6)
        np.testing.assert_allclose(y[:, 0], jnp.zeros_like(u[:, 0]), atol=1e-6)


class TestRefInternals:
    def test_fftconv_matches_direct_sum(self):
        """FFT path equals the O(L²) Toeplitz definition (paper Eq. 1/2)."""
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        L = 19
        h = _rand(ks[0], L)
        v = _rand(ks[1], L)
        direct = jnp.array(
            [sum(h[t - n] * v[n] for n in range(t + 1)) for t in range(L)]
        )
        np.testing.assert_allclose(ref.causal_fftconv(h, v), direct, rtol=1e-4, atol=1e-4)

    def test_hyena_matrix_equals_recurrence(self):
        """y = H(u) v: the materialized matrix path equals the FFT recurrence."""
        ks = jax.random.split(jax.random.PRNGKey(4), 4)
        N, L = 2, 24
        xs = _rand(ks[0], N, L)
        hs = _rand(ks[1], N, L)
        biases = _rand(ks[2], N)
        v = _rand(ks[3], L)
        H = ref.hyena_matrix(xs, hs, biases)
        want = H @ v
        got = ref.hyena_recurrence(
            v[None, None, :], xs[:, None, None, :], hs[:, None, :], biases[:, None]
        )[0, 0]
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_hyena_matrix_causal(self):
        """Prop 3.1: causal filters ⇒ H(u) is lower triangular."""
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        N, L = 3, 16
        H = ref.hyena_matrix(_rand(ks[0], N, L), _rand(ks[1], N, L), _rand(ks[2], N))
        upper = jnp.triu(jnp.ones((L, L)), k=1)
        assert float(jnp.abs(H * upper).max()) < 1e-5
