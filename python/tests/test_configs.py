"""Config registry sanity: every experiment artifact is well-formed and its
model builds (shape-level, via eval_shape — no FLOPs spent)."""
import jax
import jax.numpy as jnp
import pytest

from compile import model, ops
from compile.configs import CONFIGS


def test_registry_covers_experiment_index():
    """DESIGN.md §5: at least one artifact per experiment family."""
    names = set(CONFIGS)
    for probe in [
        "ar_implicit_L128", "ar_conv1d_L512",         # E1
        "op_hyena_L1024", "op_rwkv_L1024",            # E2
        "lm_hyena3slim_wt",                           # E3
        "lm_gpt_s", "lm_hyena_m",                     # E4
        "rt_attn_L1024", "rt_hyena_L8192",            # E6
        "rt_hyenapallas_L256",                        # E6 pallas path
        "img_vit", "img_hyena",                       # E7
        "arith_d3",                                   # E9
        "abl_order3", "abl_noshort",                  # ablations
        "golden_tiny",
    ]:
        assert probe in names, probe


def test_attention_8k_excluded():
    """Tab 4.2 / Fig 4.3 mark exact attention OOM at the longest lengths."""
    assert "rt_attn_L8192" not in CONFIGS
    assert "rt_flash_L8192" in CONFIGS


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_config_fields(name):
    cfg = CONFIGS[name]
    assert cfg["family"] in ("lm", "img")
    assert cfg["mixer"] in ops.OPS
    assert cfg["seqlen"] >= 8
    assert cfg["batch"] >= 1
    assert cfg["depth"] >= 1
    if cfg["family"] == "lm":
        assert cfg["vocab"] >= 8
    else:
        assert cfg["classes"] >= 2
        assert cfg["seqlen"] == (cfg["image"] // cfg["patch"]) ** 2


@pytest.mark.parametrize(
    "name",
    ["golden_tiny", "op_rwkv_L1024", "lm_hyena3slim_wt", "img_hyena", "abl_noshort"],
)
def test_models_build_at_shape_level(name):
    cfg = CONFIGS[name]
    init = model.init_lm if cfg["family"] == "lm" else model.init_img
    fwd = model.forward_lm if cfg["family"] == "lm" else model.forward_img
    params = jax.eval_shape(lambda s: init(s, cfg), jnp.zeros((), jnp.int32))
    if cfg["family"] == "lm":
        data = jax.ShapeDtypeStruct((cfg["batch"], cfg["seqlen"]), jnp.int32)
        out = jax.eval_shape(lambda p, t: fwd(p, t, cfg), params, data)
        assert out.shape == (cfg["batch"], cfg["seqlen"], cfg["vocab"])
    else:
        data = jax.ShapeDtypeStruct(
            (cfg["batch"], cfg["image"], cfg["image"]), jnp.float32
        )
        out = jax.eval_shape(lambda p, t: fwd(p, t, cfg), params, data)
        assert out.shape == (cfg["batch"], cfg["classes"])


def test_slim_is_deeper_thinner_mlp():
    """Tab 4.3: Hyena-slim trades MLP width for depth at ~equal params."""
    base = CONFIGS["lm_hyena3_wt"]
    slim = CONFIGS["lm_hyena3slim_wt"]
    assert slim["depth"] > base["depth"]
    assert slim["mlp_ratio"] < base["mlp_ratio"]


def test_flop_accounting_matches_between_attention_variants():
    """attn and flash share FLOP counts (same math)."""
    a = model.flops_per_token_lm(dict(CONFIGS["op_attn_L1024"]))
    f = model.flops_per_token_lm(dict(CONFIGS["op_flash_L1024"]))
    assert a == f
