"""Tests for scripts/rustcheck — the compiler-independent Rust gate.

Each pass gets a known-bad fixture mini-crate (written under tmp_path) that
must produce exactly the expected finding, plus clean fixtures that must not.
The suite ends with the two gate assertions: the real tree is rustcheck-clean,
and a seeded defect injected into a copy of the tree flips `--strict` to a
nonzero exit.
"""

import json
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[2]
sys.path.insert(0, str(ROOT / "scripts"))

from rustcheck.driver import run_repo  # noqa: E402


def mk(root: Path, rel: str, text: str) -> None:
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)


def findings(root: Path):
    return run_repo(root)["findings"]


def rules(fds):
    return {f["rule"] for f in fds}


CLEAN_LIB = """\
pub mod a;

pub fn top(x: u32) -> u32 {
    a::helper(x)
}
"""

CLEAN_A = """\
pub fn helper(v: u32) -> u32 {
    v + 1
}
"""


# ---------------------------------------------------------------------------
# lexer + balance
# ---------------------------------------------------------------------------


def test_clean_mini_crate_is_green(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", CLEAN_LIB)
    mk(tmp_path, "rust/src/a.rs", CLEAN_A)
    assert findings(tmp_path) == []


def test_unbalanced_delimiters(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", "pub fn f() { if true { 1; }\n")
    fds = findings(tmp_path)
    assert "balance" in rules(fds)
    assert any("unclosed" in f["message"] for f in fds)


def test_mismatched_delimiter_reports_both_lines(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", "pub fn f(x: [u32; 4)) {}\n")
    fds = findings(tmp_path)
    assert "balance" in rules(fds)


def test_unclosed_string_literal(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", 'pub fn f() { let _s = "oops; }\n')
    assert "lexer" in rules(findings(tmp_path))


def test_lexer_handles_tricky_literals(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", r'''
//! Doc with a } brace and an " unmatched quote.
pub fn f<'a>(x: &'a str) -> char {
    let _raw = r#"embedded "quotes" and { braces"#;
    let _byte = b"bytes { [";
    let _b = b'{';
    let _sp = ' ';
    let _esc = '\n';
    let _q = '\'';
    /* nested /* block */ comment with ) */
    'x'
}
''')
    assert findings(tmp_path) == []


# ---------------------------------------------------------------------------
# module graph
# ---------------------------------------------------------------------------


def test_dangling_mod_decl(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", "mod ghost;\n")
    fds = findings(tmp_path)
    assert "mod-unresolved" in rules(fds)
    assert any("ghost" in f["message"] for f in fds)


def test_orphan_file(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", CLEAN_LIB)
    mk(tmp_path, "rust/src/a.rs", CLEAN_A)
    mk(tmp_path, "rust/src/lonely.rs", "pub fn nobody_calls_me() {}\n")
    fds = findings(tmp_path)
    assert [f["rule"] for f in fds] == ["orphan-file"]
    assert fds[0]["file"] == "rust/src/lonely.rs"


def test_mod_rs_layout_resolves(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", "pub mod deep;\npub fn f() { deep::inner::g(); }\n")
    mk(tmp_path, "rust/src/deep/mod.rs", "pub mod inner;\n")
    mk(tmp_path, "rust/src/deep/inner.rs", "pub fn g() {}\n")
    assert findings(tmp_path) == []


def test_use_unresolved(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", "pub mod a;\nuse crate::a::no_such_item;\n")
    mk(tmp_path, "rust/src/a.rs", CLEAN_A)
    fds = findings(tmp_path)
    assert "use-unresolved" in rules(fds)
    assert any("no_such_item" in f["message"] for f in fds)


def test_use_of_real_items_resolves(tmp_path):
    mk(tmp_path, "rust/src/lib.rs",
       "pub mod a;\npub use a::{helper, Thing};\nuse crate::a::Thing as T2;\n")
    mk(tmp_path, "rust/src/a.rs", CLEAN_A + "pub struct Thing(pub u32);\n")
    assert findings(tmp_path) == []


# ---------------------------------------------------------------------------
# item index: duplicates, arity, trait completeness
# ---------------------------------------------------------------------------


def test_duplicate_fn(tmp_path):
    mk(tmp_path, "rust/src/lib.rs",
       "pub fn f(x: u32) -> u32 { x }\npub fn f(x: u32) -> u32 { x + 1 }\n")
    assert "duplicate" in rules(findings(tmp_path))


def test_cfg_gated_twins_are_not_duplicates(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", '''
#[cfg(target_arch = "x86_64")]
pub fn pick() -> u32 { 1 }
#[cfg(target_arch = "aarch64")]
pub fn pick() -> u32 { 2 }
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn pick() -> u32 { 0 }
''')
    assert findings(tmp_path) == []


def test_call_arity_mismatch(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", """
pub mod a;
pub fn f(x: u32, y: u32) -> u32 { x + y }
pub fn g() -> u32 { f(1) }
""")
    mk(tmp_path, "rust/src/a.rs", "pub fn h() -> u32 { crate::f(1, 2, 3) }\n")
    fds = [f for f in findings(tmp_path) if f["rule"] == "arity"]
    assert len(fds) == 2
    msgs = " ".join(f["message"] for f in fds)
    assert "passes 1" in msgs and "passes 3" in msgs


def test_closure_args_do_not_false_positive(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", """
pub fn apply(f: impl Fn(u32, u32) -> u32) -> u32 { f(1, 2) }
pub fn g() -> u32 { apply(|a, b| a + b) }
""")
    assert findings(tmp_path) == []


def test_trait_impl_missing_required_method(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", """
pub trait Backend {
    fn step(&mut self, n: u32) -> u32;
    fn name(&self) -> u32 { 0 }
}
pub struct Native;
impl Backend for Native {
    fn name(&self) -> u32 { 1 }
}
""")
    fds = [f for f in findings(tmp_path) if f["rule"] == "trait-impl"]
    assert len(fds) == 1
    assert "step" in fds[0]["message"]


def test_trait_impl_with_all_required_is_green(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", """
pub trait Backend {
    fn step(&mut self, n: u32) -> u32;
    fn name(&self) -> u32 { 0 }
}
pub struct Native;
impl Backend for Native {
    fn step(&mut self, n: u32) -> u32 { n }
}
""")
    assert findings(tmp_path) == []


# ---------------------------------------------------------------------------
# struct-literal field names
# ---------------------------------------------------------------------------


def test_struct_lit_unknown_field(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", """
pub struct Point { pub x: f32, pub y: f32 }
pub fn mk() -> Point {
    Point { x: 1.0, z: 2.0 }
}
""")
    fds = [f for f in findings(tmp_path) if f["rule"] == "struct-lit-field"]
    assert len(fds) == 1
    assert "`z`" in fds[0]["message"] and "x, y" in fds[0]["message"]


def test_struct_pattern_unknown_field(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", """
pub struct Point { pub x: f32, pub y: f32 }
pub fn get(p: Point) -> f32 {
    let Point { x, w } = p;
    let _ = w;
    x
}
""")
    fds = [f for f in findings(tmp_path) if f["rule"] == "struct-lit-field"]
    assert len(fds) == 1
    assert "`w`" in fds[0]["message"]


def test_struct_lit_cross_module_resolution(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", "pub mod geo;\npub mod user;\n")
    mk(tmp_path, "rust/src/geo.rs",
       "pub struct Point { pub x: f32, pub y: f32 }\n")
    mk(tmp_path, "rust/src/user.rs", """
use crate::geo::Point;
pub fn mk() -> Point {
    Point { x: 1.0, why: 2.0 }
}
""")
    fds = [f for f in findings(tmp_path) if f["rule"] == "struct-lit-field"]
    assert len(fds) == 1
    assert fds[0]["file"] == "rust/src/user.rs"
    assert "rust/src/geo.rs" in fds[0]["message"]


def test_struct_lit_clean_forms(tmp_path):
    # Shorthand, nesting, FRU `..base`, rest patterns, Self, generics,
    # match arms, enum paths in `if` conditions, and plain blocks after
    # uppercase constants must all stay silent.
    mk(tmp_path, "rust/src/lib.rs", """
pub struct Point { pub x: f32, pub y: f32 }
pub struct Wrap { pub p: Point, pub tag: u32 }
pub struct Generic<T> { pub item: T, pub len: usize }
pub enum State { Idle, Busy }
pub const LIMIT: usize = 4;

impl Point {
    pub fn origin() -> Self {
        Self { x: 0.0, y: 0.0 }
    }
}

pub fn build(x: f32, y: f32) -> Wrap {
    let p = Point { x, y };
    Wrap { p: Point { x: 1.0, ..p }, tag: 0 }
}

pub fn read(w: &Wrap) -> f32 {
    let Wrap { p: Point { x, .. }, .. } = w;
    let g = Generic { item: *x, len: 1 };
    match w.tag {
        0 => g.item,
        _ => 0.0,
    }
}

pub fn classify(s: State, n: usize) -> usize {
    if let State::Busy = s { return n; }
    if n == LIMIT { n } else { LIMIT }
}
""")
    assert findings(tmp_path) == []


# ---------------------------------------------------------------------------
# targeted lints
# ---------------------------------------------------------------------------


def test_partial_cmp_unwrap(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", """
pub fn worst(xs: &[f32]) -> f32 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s[0]
}
""")
    assert "partial-cmp-unwrap" in rules(findings(tmp_path))


def test_total_cmp_is_clean(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", """
pub fn worst(xs: &[f32]) -> f32 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    s[0]
}
""")
    assert findings(tmp_path) == []


def test_unsafe_without_safety_comment(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", """
pub fn read(p: *const u32) -> u32 {
    unsafe { *p }
}
""")
    fds = [f for f in findings(tmp_path) if f["rule"] == "unsafe-no-safety"]
    assert len(fds) == 1


def test_unsafe_with_safety_comment_passes(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", """
pub fn read(p: *const u32, q: *const u32) -> u32 {
    // SAFETY: caller guarantees p is valid and aligned.
    let a = unsafe { *p };
    let b = unsafe { *q }; // SAFETY: ditto for q.
    a + b
}

/// Docs.
///
/// # Safety
///
/// `p` must be valid.
pub unsafe fn read_raw(p: *const u32) -> u32 {
    // SAFETY: contract forwarded from this fn's own `# Safety` section.
    unsafe { *p }
}
""")
    assert findings(tmp_path) == []


def test_nondeterminism_outside_seam(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", "pub mod net;\npub mod clock;\n")
    mk(tmp_path, "rust/src/clock.rs", """
use std::time::SystemTime;
pub fn stamp() -> u64 {
    SystemTime::now().elapsed().map(|d| d.as_secs()).unwrap_or(0)
}
""")
    mk(tmp_path, "rust/src/net/mod.rs", """
use std::time::SystemTime;
pub fn retry_after() -> u64 {
    SystemTime::now().elapsed().map(|d| d.as_secs()).unwrap_or(0)
}
""")
    fds = [f for f in findings(tmp_path) if f["rule"] == "nondeterminism"]
    assert len(fds) == 1
    assert fds[0]["file"] == "rust/src/clock.rs"


def test_nondeterminism_obs_clock_is_a_seam(tmp_path):
    """obs/clock.rs is the second sanctioned wall-clock seam; the same call
    in any sibling obs file must still fail --strict."""
    mk(tmp_path, "rust/src/lib.rs", "pub mod obs;\n")
    mk(tmp_path, "rust/src/obs/mod.rs", "pub mod clock;\npub mod trace;\n")
    mk(tmp_path, "rust/src/obs/clock.rs", """
use std::time::SystemTime;
pub fn epoch_ms() -> u128 {
    SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis())
        .unwrap_or(0)
}
""")
    mk(tmp_path, "rust/src/obs/trace.rs", """
use std::time::SystemTime;
pub fn stamp() -> u64 {
    SystemTime::now().elapsed().map(|d| d.as_secs()).unwrap_or(0)
}
""")
    fds = [f for f in findings(tmp_path) if f["rule"] == "nondeterminism"]
    assert len(fds) == 1
    assert fds[0]["file"] == "rust/src/obs/trace.rs"


KERNELS_MOD = """\
pub struct Kernels {
    pub axpy: fn(&mut [f32], &[f32], f32),
    pub dot: fn(&[f32], &[f32]) -> f32,
}
mod scalar;
pub static SCALAR: Kernels = Kernels { axpy: noop_axpy, dot: noop_dot };
fn noop_axpy(_y: &mut [f32], _w: &[f32], _a: f32) {}
fn noop_dot(_a: &[f32], _b: &[f32]) -> f32 { 0.0 }
"""


def test_kernel_table_field_drift(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", "pub mod backend;\n")
    mk(tmp_path, "rust/src/backend/mod.rs", "pub mod native;\n")
    mk(tmp_path, "rust/src/backend/native/mod.rs", "pub mod kernels;\n")
    mk(tmp_path, "rust/src/backend/native/kernels/mod.rs", KERNELS_MOD)
    mk(tmp_path, "rust/src/backend/native/kernels/scalar.rs", "")
    mk(tmp_path, "rust/src/backend/native/kernels/simd.rs", """
use super::Kernels;
fn my_axpy(_y: &mut [f32], _w: &[f32], _a: f32) {}
pub static AVX2: Kernels = Kernels { axpy: my_axpy };
""")
    fds = [f for f in findings(tmp_path) if f["rule"] == "kernel-parity"]
    assert len(fds) == 1
    assert "dot" in fds[0]["message"]


# ---------------------------------------------------------------------------
# allowlist
# ---------------------------------------------------------------------------


def test_allowlist_suppresses_justified_entries_only(tmp_path):
    mk(tmp_path, "rust/src/lib.rs", "mod ghost;\nmod wraith;\n")
    allow = tmp_path / "allow.txt"
    allow.write_text(
        "mod-unresolved | rust/src/lib.rs | ghost | fixture: intentional\n"
        "mod-unresolved | rust/src/lib.rs | wraith |\n"  # no justification
    )
    res = run_repo(tmp_path, allowlist_path=allow)
    kept = [f["message"] for f in res["findings"]]
    assert any("wraith" in m for m in kept)
    assert not any("ghost" in m for m in kept)
    assert any("ghost" in f["message"] for f in res["allowlisted"])


# ---------------------------------------------------------------------------
# the gate: real tree clean, injected defect trips --strict
# ---------------------------------------------------------------------------


def test_real_tree_is_rustcheck_clean():
    res = run_repo(ROOT)
    assert res["findings"] == [], (
        "rustcheck found unallowlisted issues in the tree:\n"
        + "\n".join(f"{f['file']}:{f['line']}: [{f['rule']}] {f['message']}"
                    for f in res["findings"])
    )


def _strict(root: Path):
    return subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "rustcheck"),
         "--root", str(root), "--strict", "--json"],
        capture_output=True, text=True,
    )


def test_cli_strict_green_on_real_tree():
    proc = _strict(ROOT)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    out = json.loads(proc.stdout)
    assert out["summary"]["findings"] == 0


@pytest.mark.parametrize("defect", [
    ("rust/src/metrics/mod.rs", "\npub fn rc_seeded() { let _ = vec![1; }\n"),
    ("rust/src/lib.rs", "\nmod rustcheck_seeded_ghost;\n"),
    ("rust/src/util/stats.rs",
     "\npub fn rc_seeded(a: f32, b: f32) -> bool "
     "{ a.partial_cmp(&b).unwrap() == std::cmp::Ordering::Less }\n"),
    ("rust/src/util/stats.rs",
     "\npub fn rc_seeded(p: *const f32) -> f32 { unsafe { *p } }\n"),
    ("rust/src/util/stats.rs",
     "\npub struct RcSeeded { pub a: u32 }\n"
     "pub fn rc_seeded() -> RcSeeded { RcSeeded { a: 1, b: 2 } }\n"),
])
def test_cli_strict_trips_on_injected_defect(tmp_path, defect):
    rel, payload = defect
    shutil.copytree(ROOT / "rust", tmp_path / "rust")
    with open(tmp_path / rel, "a") as fh:
        fh.write(payload)
    proc = _strict(tmp_path)
    assert proc.returncode == 1, (
        f"seeded defect in {rel} was not detected:\n{proc.stdout}{proc.stderr}"
    )
