"""Numerical evidence for the shape-bucketed serving path (PR 3).

The native Rust serving path routes a short prompt to the smallest causal
FFT-conv plan that covers it instead of padding to the compiled L. Causality
makes the result mathematically equal to the full-pad forward at every prompt
position; the FFT sizes differ between plans, so in f32 the agreement is to
round-off, not bitwise (the Rust e2e test pins 1e-4 relative; the full-L
bucket is pinned bitwise). This mirror measures the actual cross-plan error
in f32 so that tolerance is justified by data rather than hand-waving.

Mirrors `rust/src/backend/fft.rs::CausalConv` exactly: zero-pad both signals
to the next power of two ≥ 2L, multiply rfft spectra, truncate the irfft.
"""

import numpy as np


def causal_conv_f32(h, v, l):
    """f32 causal FFT convolution at plan length l (numpy rfft mirror)."""
    n = 1 << int(np.ceil(np.log2(max(2 * l, 2))))
    hp = np.zeros(n, dtype=np.float32)
    vp = np.zeros(n, dtype=np.float32)
    hp[: len(h)] = h[:l].astype(np.float32)
    vp[: len(v)] = v[:l].astype(np.float32)
    spec = (np.fft.rfft(hp) * np.fft.rfft(vp)).astype(np.complex64)
    return np.fft.irfft(spec, n=n).astype(np.float32)[:l]


def bucket_ladder(full, levels=4, min_len=8):
    lens = [full]
    l = full
    for _ in range(levels - 1):
        l //= 2
        if l < min_len:
            break
        lens.append(l)
    return sorted(set(lens))


def test_bucket_ladder_matches_rust():
    assert bucket_ladder(256) == [32, 64, 128, 256]
    assert bucket_ladder(16) == [8, 16]
    assert bucket_ladder(8) == [8]
    assert bucket_ladder(48, 3) == [12, 24, 48]


def test_bucketed_prefix_agrees_within_f32_roundoff():
    """Prefix logits claim: conv at the bucket plan equals the full-plan
    conv on the prompt support, to f32 round-off well inside 1e-4."""
    rng = np.random.default_rng(0)
    worst = 0.0
    for full in (256, 1024):
        for lb in bucket_ladder(full)[:-1]:
            for _ in range(20):
                h = rng.standard_normal(full).astype(np.float32)
                v = np.zeros(full, dtype=np.float32)
                p = rng.integers(1, lb + 1)  # prompt support ≤ bucket
                v[:p] = rng.standard_normal(p).astype(np.float32)
                y_full = causal_conv_f32(h, v, full)[:lb]
                y_bkt = causal_conv_f32(h[:lb], v[:lb], lb)
                rel = np.max(
                    np.abs(y_full - y_bkt)
                    / (1.0 + np.maximum(np.abs(y_full), np.abs(y_bkt)))
                )
                worst = max(worst, float(rel))
    # Measured ~1e-6..1e-5; the Rust test's 1e-4 leaves an order of margin.
    assert worst < 5e-5, f"cross-plan f32 disagreement too large: {worst}"


def test_same_plan_is_deterministic():
    """Same plan + same inputs → bitwise-identical output (the full-bucket
    bitwise guarantee of the Rust serving path)."""
    rng = np.random.default_rng(1)
    h = rng.standard_normal(128).astype(np.float32)
    v = rng.standard_normal(128).astype(np.float32)
    a = causal_conv_f32(h, v, 128)
    b = causal_conv_f32(h, v, 128)
    assert np.array_equal(a, b)
