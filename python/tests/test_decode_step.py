"""Numerical evidence for the streaming decode path (PR 4).

The native Rust decode path keeps per-session histories of every long-conv
input resident and serves each new position as a single time-domain dot
against the buffered history — O(L) per token — falling back to the bucketed
FFT path only for the prefill (DESIGN.md §Decode). The claims mirrored here:

1. The incremental dot (`rust/src/backend/fft.rs::causal_dot_step`: a
   forward dot of the history against the *reversed* filter tail) equals the
   direct causal conv exactly, and the FFT conv to f32 round-off — so the
   streamed token stream can be pinned token-identical against recompute.
2. Composed through the Hyena recurrence (v ← gate ⊙ (h ∗ v + bias ⊙ v)),
   stepping position-by-position from an FFT-prefilled history stays within
   f32 round-off of recomputing the whole prefix with FFTs each round —
   the exactness contract the Rust e2e tests pin at the model level.
"""

import numpy as np


def causal_conv_fft_f32(h, v, l):
    """f32 causal FFT conv at plan length l (CausalConv mirror)."""
    n = 1 << int(np.ceil(np.log2(max(2 * l, 2))))
    hp = np.zeros(n, dtype=np.float32)
    vp = np.zeros(n, dtype=np.float32)
    hp[:l] = h[:l].astype(np.float32)
    vp[:l] = v[:l].astype(np.float32)
    spec = (np.fft.rfft(hp) * np.fft.rfft(vp)).astype(np.complex64)
    return np.fft.irfft(spec, n=n).astype(np.float32)[:l]


def causal_dot_step(hrev, hist):
    """One streaming conv output: y[t] = Σ_{s≤t} h[t−s]·v[s], as the forward
    f32 dot of the history against the reversed filter's tail (the layout of
    `causal_dot_step` in fft.rs)."""
    n = len(hist)
    tail = hrev[len(hrev) - n :].astype(np.float32)
    return np.float32(np.dot(tail, hist.astype(np.float32)))


def test_incremental_dot_matches_direct_conv_exactly_in_shape():
    """Position-by-position streaming equals the direct O(L²) conv."""
    rng = np.random.default_rng(1)
    for l in (1, 7, 64, 300):
        h = rng.standard_normal(l).astype(np.float32)
        v = rng.standard_normal(l).astype(np.float32)
        hrev = h[::-1].copy()
        direct = np.convolve(h.astype(np.float64), v.astype(np.float64))[:l]
        for t in range(l):
            got = causal_dot_step(hrev, v[: t + 1])
            assert abs(got - direct[t]) <= 1e-4 * (1.0 + abs(direct[t])), (
                f"L={l} t={t}: {got} vs {direct[t]}"
            )


def test_incremental_dot_agrees_with_fft_conv():
    """The decode dot vs the serving path's FFT conv: f32 round-off only.
    This is the cross-method error budget behind the Rust 1e-3 logits
    tolerance and the token-identical greedy pin."""
    rng = np.random.default_rng(2)
    worst = 0.0
    for l in (64, 256, 1024, 4096):
        h = rng.standard_normal(l).astype(np.float32)
        v = rng.standard_normal(l).astype(np.float32)
        hrev = h[::-1].copy()
        y_fft = causal_conv_fft_f32(h, v, l)
        for t in range(0, l, max(1, l // 64)):
            got = causal_dot_step(hrev, v[: t + 1])
            rel = abs(got - y_fft[t]) / (1.0 + abs(y_fft[t]))
            worst = max(worst, rel)
    assert worst < 2e-4, f"dot vs FFT conv drifted: {worst}"


def hyena_recurrence_fft(z_value, gates, filters, biases, l):
    """Reference: the order-N recurrence evaluated with full FFT convs over
    the whole length (the recompute/serving path). Returns every v_order
    history and the final output."""
    v = z_value.astype(np.float32)
    hists = []
    for h, bias, gate in zip(filters, biases, gates):
        hists.append(v.copy())
        c = causal_conv_fft_f32(h, v, l) + np.float32(bias) * v
        v = gate.astype(np.float32) * c
    return hists, v


def test_streamed_recurrence_matches_fft_recompute():
    """FFT-prefill the first p positions, then stream positions p..l one at
    a time with incremental dots (the DecodeState walk): the final outputs
    must agree with full FFT recompute to f32 round-off."""
    rng = np.random.default_rng(3)
    n_order = 2
    for l, p in ((64, 24), (256, 100), (1024, 500)):
        z = rng.standard_normal(l).astype(np.float32)
        gates = [rng.standard_normal(l).astype(np.float32) for _ in range(n_order)]
        filters = [rng.standard_normal(l).astype(np.float32) for _ in range(n_order)]
        biases = [np.float32(rng.standard_normal() * 0.2) for _ in range(n_order)]
        hrevs = [h[::-1].copy() for h in filters]

        # Full recompute reference.
        _, want = hyena_recurrence_fft(z, gates, filters, biases, l)

        # Prefill: histories of v_0..v_{N−1} for positions < p come from
        # the FFT path at the prefix length (the bucketed prefill).
        pre_hists, _ = hyena_recurrence_fft(z[:p], [g[:p] for g in gates], filters, biases, p)
        hists = [np.zeros(l, dtype=np.float32) for _ in range(n_order)]
        for o in range(n_order):
            hists[o][:p] = pre_hists[o]

        # Stream positions p..l: append v_order[t], dot, gate — the exact
        # walk of `NativeModel::decode_step_into`.
        out = np.zeros(l, dtype=np.float32)
        for t in range(p, l):
            v_t = z[t]
            for o in range(n_order):
                hists[o][t] = v_t
                c = causal_dot_step(hrevs[o], hists[o][: t + 1]) + biases[o] * hists[o][t]
                v_t = gates[o][t] * c
            out[t] = v_t

        rel = np.max(
            np.abs(out[p:] - want[p:]) / (1.0 + np.maximum(np.abs(out[p:]), np.abs(want[p:])))
        )
        assert rel < 2e-3, f"L={l} p={p}: streamed recurrence drifted {rel}"
