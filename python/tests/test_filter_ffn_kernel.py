"""Pallas filter-FFN kernel vs the jnp reference parametrization path."""
import math

import numpy as np
import pytest

# Containers without the compile-path extras (jax, hypothesis) must skip this
# module cleanly at collection time instead of failing with ImportError.
jax = pytest.importorskip("jax", reason="compile-path tests need jax")
pytest.importorskip("hypothesis", reason="compile-path tests need hypothesis")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import filters
from compile.kernels.filter_ffn import filter_ffn_pallas, vmem_estimate_bytes

CFG = dict(pe_features=4, filter_width=16, filter_depth=3, sine_freq=14.0)


def _window(N, D, L, cfg):
    """Reference decay window matching filters.materialize_implicit."""
    fast = cfg.get("decay_fast", 0.3)
    slow = cfg.get("decay_slow", 1.5)
    shift = cfg.get("window_shift", 0.01)
    t = jnp.arange(L, dtype=jnp.float32) / max(L, 1)
    alpha = jnp.exp(jnp.linspace(math.log(fast), math.log(slow), N * D)).reshape(N, D)
    return jnp.exp(-alpha[..., None] * t * L / (0.3 * L)) + shift


def _run_kernel(params, N, D, L, cfg, block_l=64):
    depth = cfg["filter_depth"]
    pe = filters.positional_encoding(L, cfg["pe_features"])
    win = _window(N, D, L, cfg)              # (N, D, L)
    win_flat = win.reshape(N * D, L).T       # (L, ND)
    ws = [params[f"w{i}"] for i in range(depth)]
    bs = [params[f"b{i}"] for i in range(depth)]
    h = filter_ffn_pallas(pe, win_flat, ws, bs, cfg["sine_freq"], block_l=block_l)
    return h.T.reshape(N, D, L)


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(1, 3),
    d=st.integers(1, 8),
    logl=st.integers(3, 7),
    block=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference_path(n, d, logl, block, seed):
    L = 2**logl
    p = filters.init_filter(jax.random.PRNGKey(seed), "implicit", n, d, CFG)
    want = filters.materialize_filter(p, "implicit", n, d, L, CFG)
    got = _run_kernel(p, n, d, L, CFG, block_l=block)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_ragged_length_padding():
    """L not divisible by the block: pad region must not corrupt output."""
    N, D, L = 2, 4, 50
    p = filters.init_filter(jax.random.PRNGKey(0), "implicit", N, D, CFG)
    want = filters.materialize_filter(p, "implicit", N, D, L, CFG)
    got = _run_kernel(p, N, D, L, CFG, block_l=16)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_vmem_estimate_small():
    # Production-ish shapes stay well inside 16 MiB VMEM.
    assert vmem_estimate_bytes(256, 17, 64, 2 * 768) < 16 * 2**20
