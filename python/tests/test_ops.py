"""Operator-level tests: every mixer is causal, shape-stable and trainable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import ops

CFG = dict(
    width=16, order=2, n_heads=2, short_filter=3, filter_kind="implicit",
    pe_features=4, filter_width=16, filter_depth=3, sine_freq=14.0,
    filter_size=8, fno_modes=8, ssm_state=4, tf_order=4,
    aft_window=16, flash_chunk=8, use_pallas=False,
)
KINDS = list(ops.OPS)


def _u(B=2, L=24, D=16, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, L, D))


@pytest.mark.parametrize("kind", KINDS)
def test_shape_and_finite(kind):
    p = ops.init_op(jax.random.PRNGKey(0), kind, CFG)
    y = ops.apply_op(p, kind, _u(), CFG)
    assert y.shape == (2, 24, 16)
    assert bool(jnp.isfinite(y).all())


@pytest.mark.parametrize("kind", KINDS)
def test_causality(kind):
    """Future perturbation must not leak into past outputs (Prop. 3.1 /
    causal masking for attention variants)."""
    p = ops.init_op(jax.random.PRNGKey(1), kind, CFG)
    u = _u(seed=2)
    t = 13
    y0 = ops.apply_op(p, kind, u, CFG)
    u2 = u.at[:, t:, :].add(3.0)
    y1 = ops.apply_op(p, kind, u2, CFG)
    np.testing.assert_allclose(y0[:, :t], y1[:, :t], rtol=2e-4, atol=2e-4)
    assert float(jnp.abs(y0[:, t:] - y1[:, t:]).max()) > 1e-4


@pytest.mark.parametrize("kind", KINDS)
def test_gradients_flow(kind):
    p = ops.init_op(jax.random.PRNGKey(3), kind, CFG)

    def loss(p):
        return (ops.apply_op(p, kind, _u(seed=4), CFG) ** 2).mean()

    g = jax.grad(loss)(p)
    total = sum(float(jnp.abs(v).sum()) for v in g.values())
    assert np.isfinite(total) and total > 0.0


def test_flash_matches_exact_attention():
    """Online-softmax chunked attention == materialized attention."""
    p = ops.init_op(jax.random.PRNGKey(5), "attn", CFG)
    u = _u(B=2, L=33, seed=6)  # non-divisible length exercises padding
    exact = ops.attn_op(p, u, CFG)
    flash = ops.flash_attn_op(p, u, CFG)
    np.testing.assert_allclose(flash, exact, rtol=1e-4, atol=1e-4)


def test_hyena_pallas_matches_jnp_path():
    """The Pallas forward (DFT-matmul kernel) equals the FFT reference path."""
    cfg = dict(CFG)
    p = ops.init_op(jax.random.PRNGKey(7), "hyena", cfg)
    u = _u(B=1, L=32, seed=8)
    y_ref = ops.hyena_op(p, u, dict(cfg, use_pallas=False))
    y_pal = ops.hyena_op(p, u, dict(cfg, use_pallas=True))
    np.testing.assert_allclose(y_pal, y_ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("order", [1, 2, 3])
def test_hyena_orders(order):
    cfg = dict(CFG, order=order)
    p = ops.init_op(jax.random.PRNGKey(9), "hyena", cfg)
    y = ops.apply_op(p, "hyena", _u(), cfg)
    assert y.shape == (2, 24, 16)
    # Param count of the input projection scales with (order+1)·D.
    assert p["proj_w"].shape == (16, (order + 1) * 16)


def test_hyena_no_short_filter():
    cfg = dict(CFG, short_filter=0)
    p = ops.init_op(jax.random.PRNGKey(10), "hyena", cfg)
    assert "short_w" not in p
    y = ops.apply_op(p, "hyena", _u(), cfg)
    assert bool(jnp.isfinite(y).all())


def test_hyena_is_linear_in_v_projection():
    """Hyena encodes y = H(u)·v: with frozen gates, scaling the value path
    scales the output linearly (data-controlled *linear* operator)."""
    import compile.filters as filters
    from compile.kernels import ref

    N, D, L, B = 2, 4, 16, 1
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    v = jax.random.normal(ks[0], (B, D, L))
    xs = jax.random.normal(ks[1], (N, B, D, L))
    hs = jax.random.normal(ks[2], (N, D, L))
    b = jax.random.normal(ks[3], (N, D))
    y1 = ref.hyena_recurrence(v, xs, hs, b)
    y2 = ref.hyena_recurrence(2.5 * v, xs, hs, b)
    np.testing.assert_allclose(y2, 2.5 * y1, rtol=1e-4, atol=1e-4)


def test_rwkv_decay_forgets():
    """With strong decay, RWKV output at t is dominated by recent tokens."""
    cfg = dict(CFG)
    p = ops.init_op(jax.random.PRNGKey(12), "rwkv", cfg)
    p = dict(p, decay=jnp.full((16,), 8.0))  # softplus(8) ≈ 8 → decay ≈ e^-8
    u = _u(B=1, L=30, seed=13)
    u2 = u.at[:, :5, :].add(5.0)  # perturb the distant past
    y1 = ops.apply_op(p, "rwkv", u, cfg)
    y2 = ops.apply_op(p, "rwkv", u2, cfg)
    # far-future outputs barely move
    assert float(jnp.abs(y1[:, -1] - y2[:, -1]).max()) < 0.3
