"""Numpy mirror of the Rust `ChunkedCausalConv` (rust/src/backend/fft.rs).

Overlap-save block convolution is the same linear causal convolution the
monolithic FFT computes: each block transforms [carry (filter-1 preceding
input samples) ++ chunk], multiplies by the filter spectrum, inverse
transforms, and keeps the outputs past the carry. This mirror pins the
algorithm 1:1 — plan geometry (fft size = next_pow2(chunk + filter - 1)),
carry semantics (all history so far, capped at filter - 1), ragged final
chunks, the chunk < filter rejection — so the exactness contract of
DESIGN.md §Long-context stays executable in cargo-less containers.

Pure numpy; no repo imports, no jax, no hypothesis.
"""
import numpy as np
import pytest


def next_pow2(n):
    p = 1
    while p < n:
        p *= 2
    return p


class OverlapSave:
    """Mirror of ChunkedCausalConv: fixed chunk/filter geometry, streaming
    carry, per-block rfft/spec-mul/irfft."""

    def __init__(self, chunk, filter_len, fft_size=None):
        if filter_len == 0 or chunk < filter_len:
            raise ValueError(f"invalid overlap-save plan: chunk {chunk} < filter {filter_len}")
        n = fft_size if fft_size is not None else max(2, next_pow2(chunk + filter_len - 1))
        if n < chunk + filter_len - 1:
            raise ValueError("fft size cannot hold chunk + filter - 1")
        self.chunk = chunk
        self.filter = filter_len
        self.n = n

    @property
    def carry_len(self):
        return self.filter - 1

    def filter_spectrum(self, h):
        assert len(h) <= self.filter
        return np.fft.rfft(h, n=self.n)

    def process_chunk(self, hspec, carry, chunk_in):
        w, cl = len(carry), len(chunk_in)
        assert w < self.filter
        assert 1 <= cl <= self.chunk
        x = np.concatenate([carry, chunk_in])
        y = np.fft.irfft(hspec * np.fft.rfft(x, n=self.n), n=self.n)
        return y[w : w + cl]

    def update_carry(self, carry, chunk_in):
        w = self.filter - 1
        if w == 0:
            return chunk_in[:0]
        return np.concatenate([carry, chunk_in])[-w:]

    def conv_streaming(self, h, v):
        hspec = self.filter_spectrum(h)
        carry = v[:0]
        out = []
        g0 = 0
        while g0 < len(v):
            cl = min(self.chunk, len(v) - g0)
            block = v[g0 : g0 + cl]
            out.append(self.process_chunk(hspec, carry, block))
            carry = self.update_carry(carry, block)
            g0 += cl
        return np.concatenate(out) if out else v[:0]


def causal_conv_direct(h_full, v):
    """Reference O(L^2) causal conv, mirroring the Rust reference."""
    l = len(v)
    y = np.zeros(l, dtype=np.float64)
    for t in range(l):
        for s in range(t + 1):
            y[t] += h_full[t - s] * v[s]
    return y


def monolithic_fft_conv(h_full, v):
    """The monolithic CausalConv path: one FFT at next_pow2(2L)."""
    l = len(v)
    n = max(2, next_pow2(2 * l))
    return np.fft.irfft(np.fft.rfft(h_full, n=n) * np.fft.rfft(v, n=n), n=n)[:l]


def pad_filter(h, l):
    h_full = np.zeros(l, dtype=h.dtype)
    support = min(len(h), l)
    h_full[:support] = h[:support]
    return h_full


def test_overlap_save_sweep_matches_direct_and_monolithic():
    # (L, chunk, filter) sweep including ragged final chunks and blocks
    # shorter than the carry — the same sweep the Rust property test runs.
    rng = np.random.default_rng(0)
    for case in range(200):
        f = int(rng.integers(1, 17))
        chunk = f + int(rng.integers(0, 24))
        l = int(rng.integers(1, 201))
        h = rng.standard_normal(f)
        v = rng.standard_normal(l)
        plan = OverlapSave(chunk, f)
        got = plan.conv_streaming(h, v)
        h_full = pad_filter(h, l)
        direct = causal_conv_direct(h_full, v)
        mono = monolithic_fft_conv(h_full, v)
        assert got.shape == (l,)
        np.testing.assert_allclose(got, direct, rtol=1e-9, atol=1e-9, err_msg=f"case {case}")
        np.testing.assert_allclose(got, mono, rtol=1e-9, atol=1e-9, err_msg=f"case {case}")


def test_overlap_save_float32_meets_rel_tolerance_vs_monolithic():
    # The acceptance bound of the Rust engine is stated in f32: chunked vs
    # monolithic <= 1e-4 relative. Run the mirror in float32 to pin it.
    rng = np.random.default_rng(1)
    for l, chunk, f in [(1000, 64, 64), (777, 100, 33), (4096, 256, 256)]:
        h = rng.standard_normal(f).astype(np.float32)
        v = rng.standard_normal(l).astype(np.float32)
        got = OverlapSave(chunk, f).conv_streaming(h, v).astype(np.float32)
        mono = monolithic_fft_conv(pad_filter(h, l), v).astype(np.float32)
        denom = 1.0 + np.maximum(np.abs(got), np.abs(mono))
        assert np.max(np.abs(got - mono) / denom) <= 1e-4


def test_chunk_equals_filter_edge():
    rng = np.random.default_rng(2)
    for l in (5, 8, 9, 37, 64):
        c = 8
        h = rng.standard_normal(c)
        v = rng.standard_normal(l)
        got = OverlapSave(c, c).conv_streaming(h, v)
        want = causal_conv_direct(pad_filter(h, l), v)
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_filter_one_has_no_carry():
    rng = np.random.default_rng(3)
    plan = OverlapSave(6, 1)
    assert plan.carry_len == 0
    v = rng.standard_normal(20)
    np.testing.assert_allclose(plan.conv_streaming(np.array([1.5]), v), 1.5 * v, rtol=1e-12)


def test_ragged_final_chunk_and_short_stream():
    # Streams shorter than one chunk, and streams whose final block is
    # ragged (L % chunk != 0), must both be exact.
    rng = np.random.default_rng(4)
    for l in (3, 7, 8, 15, 17, 30):
        f, chunk = 4, 8
        h = rng.standard_normal(f)
        v = rng.standard_normal(l)
        got = OverlapSave(chunk, f).conv_streaming(h, v)
        want = causal_conv_direct(pad_filter(h, l), v)
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_chunk_smaller_than_filter_is_rejected():
    with pytest.raises(ValueError):
        OverlapSave(4, 5)
    with pytest.raises(ValueError):
        OverlapSave(4, 0)
    with pytest.raises(ValueError):
        OverlapSave(0, 1)
    # chunk == filter is the legal edge.
    OverlapSave(4, 4)
    OverlapSave(1, 1)


def test_single_chunk_at_monolithic_fft_size_is_bitwise():
    # When the chunked plan runs at the monolithic plan's FFT size and the
    # whole signal fits one chunk (empty carry), the op sequence is the
    # monolithic transform itself — equality is exact, not approximate.
    rng = np.random.default_rng(5)
    for l in (8, 16, 33, 100):
        n = max(2, next_pow2(2 * l))
        h = rng.standard_normal(l)
        v = rng.standard_normal(l)
        got = OverlapSave(l, l, fft_size=n).conv_streaming(h, v)
        want = monolithic_fft_conv(h, v)
        assert np.array_equal(got, want), f"L={l} not bitwise"


def test_carry_accumulates_history_capped_at_filter_minus_one():
    plan = OverlapSave(8, 5)
    v = np.arange(20, dtype=np.float64)
    carry = v[:0]
    for g0 in range(0, 20, 8):
        block = v[g0 : g0 + 8]
        carry = plan.update_carry(carry, block)
        want = v[max(0, g0 + len(block) - 4) : g0 + len(block)]
        assert np.array_equal(carry, want)
