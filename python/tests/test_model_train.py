"""L2 model + training: shapes, causality, loss decrease, schedule, AOT glue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train
from compile.aot import flat_keys, flatten, unflatten

LM_CFG = dict(
    family="lm", mixer="hyena", depth=2, width=32, mlp_ratio=2.0, vocab=48,
    seqlen=32, batch=4, order=2, n_heads=2, short_filter=3, filter_kind="implicit",
    pe_features=4, filter_width=16, filter_depth=3, sine_freq=14.0,
    lr=3e-3, warmup_steps=5, total_steps=60, weight_decay=0.1,
)
IMG_CFG = dict(
    family="img", mixer="hyena", depth=2, width=32, mlp_ratio=2.0, patch=4,
    image=16, channels=1, classes=10, seqlen=16, batch=8, vocab=0, order=2,
    n_heads=2, short_filter=3, filter_kind="implicit", pe_features=4,
    filter_width=16, filter_depth=3, sine_freq=14.0, lr=3e-3,
    warmup_steps=5, total_steps=60, weight_decay=0.05,
)


def test_lm_forward_shape():
    p = model.init_lm(0, LM_CFG)
    toks = jnp.zeros((4, 32), jnp.int32)
    logits = model.forward_lm(p, toks, LM_CFG)
    assert logits.shape == (4, 32, 48)


@pytest.mark.parametrize("mixer", ["hyena", "attn", "rwkv"])
def test_lm_causal(mixer):
    cfg = dict(LM_CFG, mixer=mixer)
    p = model.init_lm(1, cfg)
    k = jax.random.PRNGKey(0)
    toks = jax.random.randint(k, (2, 32), 0, 48)
    t = 20
    l0 = model.forward_lm(p, toks, cfg)
    toks2 = toks.at[:, t:].set((toks[:, t:] + 1) % 48)
    l1 = model.forward_lm(p, toks2, cfg)
    np.testing.assert_allclose(l0[:, :t], l1[:, :t], rtol=5e-4, atol=5e-4)


def test_lm_loss_at_init_near_uniform():
    p = model.init_lm(2, LM_CFG)
    k = jax.random.PRNGKey(1)
    toks = jax.random.randint(k, (4, 32), 0, 48)
    mask = jnp.ones((4, 32))
    loss = model.lm_loss(p, toks, toks, mask, LM_CFG)
    assert abs(float(loss) - np.log(48)) < 0.5


def test_lm_trains_on_fixed_batch():
    """A few AdamW steps on one batch must drive the loss down sharply."""
    cfg = LM_CFG
    p = model.init_lm(3, cfg)
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in p.items()}
    k = jax.random.PRNGKey(2)
    toks = jax.random.randint(k, (4, 32), 0, 48)
    tgts = jnp.roll(toks, -1, axis=1)
    mask = jnp.ones((4, 32))
    step_fn = jax.jit(train.make_lm_train_step(cfg))
    losses = []
    for i in range(30):
        p, m, v, loss = step_fn(p, m, v, jnp.float32(i), toks, tgts, mask)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.6, losses[::10]


def test_mask_excludes_positions():
    p = model.init_lm(4, LM_CFG)
    k = jax.random.PRNGKey(3)
    toks = jax.random.randint(k, (4, 32), 0, 48)
    mask_half = jnp.ones((4, 32)).at[:, :16].set(0.0)
    l_half = model.lm_loss(p, toks, toks, mask_half, LM_CFG)
    # masked loss only depends on the unmasked positions' targets
    toks2 = toks.at[:, :15].set(0)
    l_half2 = model.lm_loss(p, toks2, toks.at[:, :16].set(0), mask_half, LM_CFG)
    # changing only masked-out targets leaves loss almost unchanged (inputs
    # differ so small drift allowed through the network is not tested here)
    assert np.isfinite(float(l_half)) and np.isfinite(float(l_half2))


def test_img_forward_and_train():
    p = model.init_img(0, IMG_CFG)
    k = jax.random.PRNGKey(4)
    imgs = jax.random.normal(k, (8, 16, 16))
    labels = jax.random.randint(k, (8,), 0, 10)
    logits = model.forward_img(p, imgs, IMG_CFG)
    assert logits.shape == (8, 10)
    m = {k2: jnp.zeros_like(v) for k2, v in p.items()}
    v = {k2: jnp.zeros_like(vv) for k2, vv in p.items()}
    step_fn = jax.jit(train.make_img_train_step(IMG_CFG))
    losses = []
    for i in range(25):
        p, m, v, loss = step_fn(p, m, v, jnp.float32(i), imgs, labels)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8


def test_patchify_roundtrip_structure():
    imgs = jnp.arange(2 * 8 * 8, dtype=jnp.float32).reshape(2, 8, 8)
    pt = model.patchify(imgs, 4)
    assert pt.shape == (2, 4, 16)
    # first patch is the top-left 4×4 block, row-major
    np.testing.assert_array_equal(pt[0, 0].reshape(4, 4), imgs[0, :4, :4])


def test_lr_schedule_shape():
    cfg = dict(LM_CFG, lr=1e-3, warmup_steps=10, total_steps=100, lr_min=1e-4)
    lrs = [float(train.lr_schedule(jnp.float32(s), cfg)) for s in range(100)]
    assert lrs[0] < lrs[9]                       # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-4            # hits peak
    assert lrs[99] < lrs[50] < lrs[11]           # cosine decays
    assert lrs[99] >= 1e-4 - 1e-6                # floored at lr_min


def test_adamw_decays_matrices_not_vectors():
    p = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    g = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in p.items()}
    cfg = dict(lr=0.1, warmup_steps=1, total_steps=2, weight_decay=0.5)
    new_p, _, _ = train.adamw_step(p, g, m, v, jnp.float32(1.0), cfg)
    assert float(new_p["w"][0, 0]) < 1.0   # decayed
    assert float(new_p["b"][0]) == 1.0     # not decayed


def test_flatten_order_stable():
    p = model.init_lm(5, LM_CFG)
    keys = flat_keys(p)
    assert keys == sorted(keys)
    rt = unflatten(keys, flatten(p))
    assert set(rt) == set(p)
    np.testing.assert_array_equal(rt[keys[0]], p[keys[0]])


def test_init_deterministic_in_seed():
    p1 = model.init_lm(7, LM_CFG)
    p2 = model.init_lm(7, LM_CFG)
    p3 = model.init_lm(8, LM_CFG)
    np.testing.assert_array_equal(p1["embed"], p2["embed"])
    assert float(jnp.abs(p1["embed"] - p3["embed"]).max()) > 0.0


def test_flops_accounting_sane():
    """Hyena FLOPs/token below attention's at long L (the paper's 20% claim
    direction), and both positive."""
    base = dict(LM_CFG, seqlen=2048, width=128, depth=4)
    f_attn = model.flops_per_token_lm(dict(base, mixer="attn"))
    f_hyena = model.flops_per_token_lm(dict(base, mixer="hyena", order=2))
    assert 0 < f_hyena < f_attn
