"""AOT pipeline tests: lowering emits valid HLO text + manifest contract."""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.aot import build_artifacts, flat_keys, to_hlo_text
from compile.configs import CONFIGS


TINY = dict(
    CONFIGS["golden_tiny"],
    depth=1,
    width=16,
    vocab=16,
    seqlen=8,
    batch=2,
    filter_width=8,
    pe_features=2,
)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    build_artifacts("tiny_test", TINY, out, True)
    return os.path.join(out, "tiny_test")


def test_emits_all_files(built):
    for f in ["manifest.json", "init.hlo.txt", "forward.hlo.txt",
              "train_step.hlo.txt", "filters.hlo.txt"]:
        assert os.path.exists(os.path.join(built, f)), f


def test_manifest_schema(built):
    with open(os.path.join(built, "manifest.json")) as f:
        m = json.load(f)
    assert m["name"] == "tiny_test"
    assert m["has_train_step"] is True
    assert m["has_filters"] is True
    names = [p["name"] for p in m["params"]]
    assert names == sorted(names), "params must be in flattening order"
    total = sum(
        int(jnp.prod(jnp.array(p["shape"] or [1]))) for p in m["params"]
    )
    assert total == m["param_count"]
    assert all(p["name"].startswith("blocks.0.mixer.filter.") for p in m["params"]
               if p["name"] in m["filter_params"])
    assert m["flops_per_step"] > 0


def test_hlo_text_is_parseable_shape(built):
    txt = open(os.path.join(built, "forward.hlo.txt")).read()
    assert txt.startswith("HloModule")
    assert "ENTRY" in txt


def test_train_step_records_donation(built):
    txt = open(os.path.join(built, "train_step.hlo.txt")).read()
    assert "input_output_alias" in txt, "params/m/v must be donated (§Perf L2)"


def test_incremental_skip(built, tmp_path):
    out = str(tmp_path / "a2")
    assert build_artifacts("t2", TINY, out, True) is True
    assert build_artifacts("t2", TINY, out, False) is False  # up-to-date
    changed = dict(TINY, lr=1e-3)
    assert build_artifacts("t2", changed, out, False) is True  # config changed


def test_flat_keys_sorted_and_complete():
    p = model.init_lm(0, TINY)
    keys = flat_keys(p)
    assert keys == sorted(p.keys())
    assert len(keys) == len(p)


def test_to_hlo_text_roundtrips_simple_fn():
    def f(x):
        return (x * 2 + 1,)

    low = jax.jit(f).lower(jax.ShapeDtypeStruct((3,), jnp.float32))
    txt = to_hlo_text(low)
    assert "HloModule" in txt and "ENTRY" in txt
