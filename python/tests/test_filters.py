"""Filter parametrizations: shapes, causality-by-construction, spectra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import filters

CFG = dict(
    pe_features=8, filter_width=32, filter_depth=4, sine_freq=14.0,
    filter_size=16, fno_modes=16, ssm_state=8, tf_order=8,
)
KINDS = ["implicit", "ckconv", "conv1d", "fno", "ssm", "tf"]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("N,D,L", [(1, 4, 32), (2, 8, 64), (3, 2, 16)])
def test_shapes_and_finite(kind, N, D, L):
    p = filters.init_filter(jax.random.PRNGKey(0), kind, N, D, CFG)
    h = filters.materialize_filter(p, kind, N, D, L, CFG)
    assert h.shape == (N, D, L)
    assert h.dtype == jnp.float32
    assert bool(jnp.isfinite(h).all())


@pytest.mark.parametrize("kind", KINDS)
def test_deterministic(kind):
    p = filters.init_filter(jax.random.PRNGKey(7), kind, 2, 4, CFG)
    h1 = filters.materialize_filter(p, kind, 2, 4, 32, CFG)
    h2 = filters.materialize_filter(p, kind, 2, 4, 32, CFG)
    np.testing.assert_array_equal(h1, h2)


def test_positional_encoding_shape_and_bounds():
    pe = filters.positional_encoding(64, 8)
    assert pe.shape == (64, 17)
    assert float(jnp.abs(pe[:, 1:]).max()) <= 1.0 + 1e-6
    # first feature is normalized time
    np.testing.assert_allclose(pe[0, 0], 0.0)
    np.testing.assert_allclose(pe[-1, 0], 1.0)


def test_implicit_decay_window_shrinks_tail():
    """The decay-windowed Hyena filter has a smaller tail than raw CKConv
    output with the same FFN params (Fig. 3.1)."""
    p = filters.init_filter(jax.random.PRNGKey(0), "implicit", 1, 8, CFG)
    L = 128
    h_win = filters.materialize_filter(p, "implicit", 1, 8, L, CFG)
    h_raw = filters.materialize_filter(p, "ckconv", 1, 8, L, CFG)
    tail_ratio_win = float(jnp.abs(h_win[..., L // 2 :]).mean() / jnp.abs(h_win).mean())
    tail_ratio_raw = float(jnp.abs(h_raw[..., L // 2 :]).mean() / jnp.abs(h_raw).mean())
    assert tail_ratio_win < tail_ratio_raw


def test_conv1d_zero_pads_beyond_filter_size():
    p = filters.init_filter(jax.random.PRNGKey(1), "conv1d", 1, 2, CFG)
    h = filters.materialize_filter(p, "conv1d", 1, 2, 64, CFG)
    assert float(jnp.abs(h[..., CFG["filter_size"]:]).max()) == 0.0


def test_ssm_filters_decay():
    """Stable diagonal SSM: |h_t| decays with t on average (spectral radius < 1)."""
    p = filters.init_filter(jax.random.PRNGKey(2), "ssm", 1, 8, CFG)
    h = filters.materialize_filter(p, "ssm", 1, 8, 256, CFG)
    head = float(jnp.abs(h[..., :32]).mean())
    tail = float(jnp.abs(h[..., -32:]).mean())
    assert tail < head


def test_tf_stable_at_init():
    p = filters.init_filter(jax.random.PRNGKey(3), "tf", 2, 4, CFG)
    h = filters.materialize_filter(p, "tf", 2, 4, 128, CFG)
    assert bool(jnp.isfinite(h).all())
    assert float(jnp.abs(h).max()) < 100.0


def test_sine_frequency_raises_high_freq_content():
    """App. D.3: larger ω_a fills in more of the spectrum at init."""
    def hf_energy(omega):
        cfg = dict(CFG, sine_freq=omega)
        p = filters.init_filter(jax.random.PRNGKey(4), "ckconv", 1, 8, cfg)
        h = filters.materialize_filter(p, "ckconv", 1, 8, 128, cfg)
        spec = jnp.abs(jnp.fft.rfft(h, axis=-1))
        return float(spec[..., 32:].sum() / spec.sum())

    assert hf_energy(14.0) > hf_energy(0.1)


def test_fno_modes_bandlimit():
    """FNO filters contain no energy above the parametrized mode count."""
    cfg = dict(CFG, fno_modes=4)
    p = filters.init_filter(jax.random.PRNGKey(5), "fno", 1, 2, cfg)
    h = filters.materialize_filter(p, "fno", 1, 2, 64, cfg)
    spec = jnp.abs(jnp.fft.rfft(h, axis=-1))
    assert float(spec[..., 4:].max()) < 1e-5
